//! Decompression-free integer GEMM (paper §4.3, Fig. 3(b)).
//!
//! `C = A · Wᵀ` where A is an SDR-compressed activation matrix
//! `[m, k]` (per-tensor scale, groups along k) and W an SDR-compressed
//! weight matrix `[n, k]` (per-channel scales, groups along k). Both
//! share the same group size so group boundaries align.
//!
//! Per output element the datapath is exactly the paper's: for each
//! group pair `p`, narrow multiplies `s_a·s_w` of the salient codes
//! (4×4-bit for W4A4 — an 8-bit product), sign via XOR, a *group-local*
//! accumulation, then **one** barrel shift by `flag_a(p) + flag_w(p)`
//! into the wide accumulator. No element is ever reconstructed to base
//! precision. `gemm_decompress` implements Fig. 3(a) — reconstruct both
//! operands, multiply at base precision — and the two are bit-identical
//! (`prop_decompression_free_equals_decompressed`), which is the claim
//! that makes the paper's hardware unit sound.

use std::sync::atomic::{AtomicU64, Ordering};

use super::packed::{decode_nibbles_into, nibble_at, ByteSdrMatrix, PackedSdrMatrix};
use super::razor::SdrMatrix;
use crate::tensor::Tensor;
use crate::util::threadpool::parallel_for;

/// Process-wide count of packed operand bytes consumed by the
/// decompression-free kernels ([`gemm_razored_packed`] and the KV
/// cache's packed attention). Benches snapshot it around a run to prove
/// the packed path actually executed — static storage accounting alone
/// cannot catch a silent fallback to the staged path.
pub static PACKED_OPERAND_BYTES: AtomicU64 = AtomicU64::new(0);

/// Record packed operand traffic (called by the packed kernels).
#[inline]
pub fn note_packed_traffic(bytes: usize) {
    PACKED_OPERAND_BYTES.fetch_add(bytes as u64, Ordering::Relaxed);
}

/// Snapshot of [`PACKED_OPERAND_BYTES`].
pub fn packed_operand_bytes() -> u64 {
    PACKED_OPERAND_BYTES.load(Ordering::Relaxed)
}

/// Wrapper making a raw `*mut T` shareable across the scoped threadpool.
/// Safe uses partition the output so no element is written twice.
struct SendPtr<T>(*mut T);
unsafe impl<T> Sync for SendPtr<T> {}
impl<T> SendPtr<T> {
    fn get(&self) -> *mut T {
        self.0
    }
}

/// Decompression-free GEMM: returns the float result
/// `C[i,j] = scale_a · scale_w[j] · Σ_p ((Σ_{t∈p} sa·sw) << (fa_p + fw_p))`.
pub fn gemm_razored(a: &SdrMatrix, w: &SdrMatrix) -> Tensor<f32> {
    let acc = gemm_razored_int(a, w);
    apply_scales(&acc, a, w)
}

/// Integer part of the decompression-free GEMM (pre-scale accumulators).
///
/// Perf note (§Perf in EXPERIMENTS.md): the sign-magnitude [`SdrCode`]
/// struct is the *storage* format; multiplying through it costs a
/// branchy conversion per MAC. We materialize each operand's signed
/// salient codes once as flat `i16` arrays — an O(mk + nk) pass
/// amortized over the O(mnk) MACs — which matches the hardware exactly
/// (the 4×4 multiplier consumes the code lines directly; sign is an
/// XOR) and lets the inner loop autovectorize.
pub fn gemm_razored_int(a: &SdrMatrix, w: &SdrMatrix) -> Tensor<i64> {
    assert_eq!(a.cols, w.cols, "reduction dims differ: {} vs {}", a.cols, w.cols);
    assert_eq!(a.spec.group, w.spec.group, "group sizes must align");
    let (m, n, k) = (a.rows, w.rows, a.cols);
    let g = a.spec.group;
    let gpr = a.groups_per_row();
    let mut c: Tensor<i64> = Tensor::zeros(&[m, n]);

    let a_signed: Vec<i16> = a.codes.iter().map(|c| c.signed() as i16).collect();
    let w_signed: Vec<i16> = w.codes.iter().map(|c| c.signed() as i16).collect();

    let cptr = SendPtr(c.data_mut().as_mut_ptr());

    parallel_for(m, |i| {
        let arow = &a_signed[i * k..(i + 1) * k];
        let aflags = a.row_flags(i);
        let crow = unsafe { std::slice::from_raw_parts_mut(cptr.get().add(i * n), n) };
        for (j, cj) in crow.iter_mut().enumerate() {
            let wrow = &w_signed[j * k..(j + 1) * k];
            let wflags = w.row_flags(j);
            let mut acc: i64 = 0;
            for p in 0..gpr {
                let lo = p * g;
                let hi = (lo + g).min(k);
                // Group-local narrow MAC: products fit easily in i32
                // (≤ 7·7·g for W4A4; ≤ 127·127·g for the A8 ablation).
                let mut part: i32 = 0;
                for (&x, &y) in arow[lo..hi].iter().zip(&wrow[lo..hi]) {
                    part += (x as i32) * (y as i32);
                }
                // One barrel shift per group pair (the Fig. 3(b) shifter).
                acc += (part as i64) << (aflags[p] + wflags[p]);
            }
            *cj = acc;
        }
    });
    c
}

/// Fig. 3(a) reference: reconstruct both operands to base precision and
/// multiply at full width. Used only to prove equivalence.
pub fn gemm_decompress(a: &SdrMatrix, w: &SdrMatrix) -> Tensor<i64> {
    let ar = a.reconstruct();
    let wr = w.reconstruct();
    let (m, n, k) = (a.rows, w.rows, a.cols);
    let mut c: Tensor<i64> = Tensor::zeros(&[m, n]);
    for i in 0..m {
        for j in 0..n {
            let mut acc: i64 = 0;
            for t in 0..k {
                acc += ar.values[i * k + t] as i64 * wr.values[j * k + t] as i64;
            }
            c.data_mut()[i * n + j] = acc;
        }
    }
    c
}

/// Turn integer accumulators into floats with the stage-1 scales.
///
/// The activation scale is looked up **per output row**: activations are
/// usually per-tensor (one scale) but per-channel activation quantization
/// is legal, and the old `scale_for_row(0)` shortcut silently mis-scaled
/// every row but the first in that case.
pub fn apply_scales(acc: &Tensor<i64>, a: &SdrMatrix, w: &SdrMatrix) -> Tensor<f32> {
    apply_scales_raw(acc, &a.scales, &w.scales)
}

/// Scale application shared by the unpacked and packed GEMM paths:
/// `out[i,j] = acc[i,j] · sa(i) · sw(j)` with each scale slice either
/// per-row (`len == rows`) or broadcast (`len == 1`).
pub fn apply_scales_raw(acc: &Tensor<i64>, a_scales: &[f32], w_scales: &[f32]) -> Tensor<f32> {
    let (m, n) = (acc.shape()[0], acc.shape()[1]);
    let pick = |s: &[f32], r: usize| if s.len() == 1 { s[0] } else { s[r] };
    let mut out = Tensor::zeros(&[m, n]);
    for i in 0..m {
        let sa = pick(a_scales, i);
        for j in 0..n {
            out.data_mut()[i * n + j] =
                acc.data()[i * n + j] as f32 * sa * pick(w_scales, j);
        }
    }
    out
}

/// Largest group the packed kernel's stack tile covers (the paper
/// evaluates g ≤ 128; matches [`super::razor::FUSED_MAX_GROUP`]).
pub const PACKED_TILE_GROUP: usize = 128;

/// Rows of `A` per parallel work item in the packed kernel. Each block's
/// activation rows are decoded once and then reused against every
/// weight tile, so the per-MAC nibble-decode cost is `1/PACKED_ROW_BLOCK`;
/// the block is also the cache unit — one packed weight row (`k/2`
/// bytes) is streamed once per block instead of once per output row.
pub const PACKED_ROW_BLOCK: usize = 8;

/// Decompression-free GEMM over **nibble-packed** operands — the packed
/// twin of [`gemm_razored`], bit-identical to it (and hence to
/// [`gemm_decompress`], the property the paper's §4.3 hardware unit
/// rests on).
///
/// The kernel never materializes an unpacked matrix: it walks the
/// nibble stores group-by-group, expanding one group at a time into a
/// stack tile (`[i16; PACKED_TILE_GROUP]` — the register file of the
/// paper's MAC array), does the narrow MACs, and applies **one** barrel
/// shift per group pair. Nibble decode is byte-wide: each packed byte
/// hits the 256-entry [`super::packed::NIBBLE_PAIR_SIGNED`] table once
/// and yields both codes, halving the decode work of the old
/// per-nibble shift/mask loop. Work is parallel over activation row blocks
/// via [`crate::util::threadpool`]; each decoded weight tile is reused
/// across the whole row block, so the packed weight stream is read once
/// per block rather than once per output row.
pub fn gemm_razored_packed(a: &PackedSdrMatrix, w: &PackedSdrMatrix) -> Tensor<i64> {
    assert_eq!(a.cols, w.cols, "reduction dims differ: {} vs {}", a.cols, w.cols);
    assert_eq!(a.spec.group, w.spec.group, "group sizes must align");
    assert!(
        a.spec.group <= PACKED_TILE_GROUP,
        "group {} exceeds the packed stack tile",
        a.spec.group
    );
    let (m, n, k) = (a.rows, w.rows, a.cols);
    let g = a.spec.group;
    let gpr = k.div_ceil(g);
    let mut c: Tensor<i64> = Tensor::zeros(&[m, n]);
    if m == 0 || n == 0 {
        return c;
    }
    note_packed_traffic(a.payload_bytes() + w.payload_bytes());
    let cptr = SendPtr(c.data_mut().as_mut_ptr());
    let iblocks = m.div_ceil(PACKED_ROW_BLOCK);

    parallel_for(iblocks, |ib| {
        let i0 = ib * PACKED_ROW_BLOCK;
        let rows = PACKED_ROW_BLOCK.min(m - i0);
        // Decode this block's activation rows once (amortized over every
        // weight row), two codes per byte via the 256-entry pair LUT;
        // flags stay packed and are read per group below.
        let mut arows = vec![0i16; rows * k];
        for r in 0..rows {
            let base = (i0 + r) * k;
            decode_nibbles_into(&a.nibbles, base, k, &mut arows[r * k..(r + 1) * k]);
        }
        let cblock =
            unsafe { std::slice::from_raw_parts_mut(cptr.get().add(i0 * n), rows * n) };
        let mut wtile = [0i16; PACKED_TILE_GROUP];
        for j in 0..n {
            let wbase = j * k;
            let wfbase = j * gpr;
            let mut accs = [0i64; PACKED_ROW_BLOCK];
            for p in 0..gpr {
                let lo = p * g;
                let glen = g.min(k - lo);
                // One weight group expanded into the stack tile, reused
                // across the whole activation row block — byte-wide
                // decode, two codes per LUT hit.
                decode_nibbles_into(&w.nibbles, wbase + lo, glen, &mut wtile[..glen]);
                let fw = nibble_at(&w.flag_bytes, wfbase + p);
                for (r, acc) in accs[..rows].iter_mut().enumerate() {
                    let arow = &arows[r * k + lo..r * k + lo + glen];
                    // Group-local narrow MAC (≤ 7·7·g fits i32).
                    let mut part: i32 = 0;
                    for (&x, &y) in arow.iter().zip(&wtile[..glen]) {
                        part += (x as i32) * (y as i32);
                    }
                    let fa = nibble_at(&a.flag_bytes, (i0 + r) * gpr + p);
                    // The one barrel shift per group pair.
                    *acc += (part as i64) << (fa + fw);
                }
            }
            for r in 0..rows {
                cblock[r * n + j] = accs[r];
            }
        }
    });
    c
}

/// Float output of the packed GEMM: integer kernel + stage-1 scales
/// (per-row activation scales handled, per-channel weight scales).
pub fn gemm_razored_packed_f32(a: &PackedSdrMatrix, w: &PackedSdrMatrix) -> Tensor<f32> {
    let acc = gemm_razored_packed(a, w);
    apply_scales_raw(&acc, &a.scales, &w.scales)
}

/// Decompression-free W4A8 GEMM: **byte-coded** A8 activations
/// ([`ByteSdrMatrix`], 7 salient bits + sign per code) against the
/// nibble-packed W4 weight store — the operand pairing of QRazor's
/// W4A8 scenarios and of a speculative verify pass, which scores draft
/// tokens at the higher-precision basis without ever reconstructing an
/// operand. Same loop structure as [`gemm_razored_packed`]: activation
/// rows decode once per row block through [`super::packed::BYTE_SIGNED`],
/// weight groups expand into the stack tile once per block, one barrel
/// shift per group pair. Bit-identical to [`gemm_razored_int`] over the
/// unpacked twins (property-tested), which keeps the staged and packed
/// W4A8 paths on one integer lattice.
pub fn gemm_razored_packed_a8(a: &ByteSdrMatrix, w: &PackedSdrMatrix) -> Tensor<i64> {
    assert_eq!(a.cols, w.cols, "reduction dims differ: {} vs {}", a.cols, w.cols);
    assert_eq!(a.spec.group, w.spec.group, "group sizes must align");
    assert!(
        a.spec.group <= PACKED_TILE_GROUP,
        "group {} exceeds the packed stack tile",
        a.spec.group
    );
    let (m, n, k) = (a.rows, w.rows, a.cols);
    let g = a.spec.group;
    let gpr = k.div_ceil(g);
    let mut c: Tensor<i64> = Tensor::zeros(&[m, n]);
    if m == 0 || n == 0 {
        return c;
    }
    note_packed_traffic(a.payload_bytes() + w.payload_bytes());
    let cptr = SendPtr(c.data_mut().as_mut_ptr());
    let iblocks = m.div_ceil(PACKED_ROW_BLOCK);

    parallel_for(iblocks, |ib| {
        let i0 = ib * PACKED_ROW_BLOCK;
        let rows = PACKED_ROW_BLOCK.min(m - i0);
        // Decode this block's activation rows once: one LUT hit per
        // code byte (the A8 operand moves twice the bytes of A4 — the
        // cost the W4A4 scenario halves).
        let mut arows = vec![0i16; rows * k];
        for (o, &b) in arows.iter_mut().zip(&a.codes[i0 * k..(i0 + rows) * k]) {
            *o = crate::sdr::packed::BYTE_SIGNED[b as usize];
        }
        let cblock =
            unsafe { std::slice::from_raw_parts_mut(cptr.get().add(i0 * n), rows * n) };
        let mut wtile = [0i16; PACKED_TILE_GROUP];
        for j in 0..n {
            let wbase = j * k;
            let wfbase = j * gpr;
            let mut accs = [0i64; PACKED_ROW_BLOCK];
            for p in 0..gpr {
                let lo = p * g;
                let glen = g.min(k - lo);
                decode_nibbles_into(&w.nibbles, wbase + lo, glen, &mut wtile[..glen]);
                let fw = nibble_at(&w.flag_bytes, wfbase + p);
                for (r, acc) in accs[..rows].iter_mut().enumerate() {
                    let arow = &arows[r * k + lo..r * k + lo + glen];
                    // Group-local narrow MAC: ≤ 127·7·g fits i32 easily.
                    let mut part: i32 = 0;
                    for (&x, &y) in arow.iter().zip(&wtile[..glen]) {
                        part += (x as i32) * (y as i32);
                    }
                    let fa = nibble_at(&a.flag_bytes, (i0 + r) * gpr + p);
                    *acc += (part as i64) << (fa + fw);
                }
            }
            for r in 0..rows {
                cblock[r * n + j] = accs[r];
            }
        }
    });
    c
}

/// Float output of the W4A8 packed GEMM: integer kernel + stage-1
/// scales, sharing [`apply_scales_raw`] with every other path.
pub fn gemm_razored_packed_a8_f32(a: &ByteSdrMatrix, w: &PackedSdrMatrix) -> Tensor<f32> {
    let acc = gemm_razored_packed_a8(a, w);
    apply_scales_raw(&acc, &a.scales, &w.scales)
}

/// Operation counts of one razored GEMM — feeds `crate::hw::opcount`
/// and the Table 8 bench.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct GemmOpCount {
    /// Narrow (4×4 or 8×8) integer multiplies.
    pub narrow_mults: u64,
    /// Group-local integer adds.
    pub adds: u64,
    /// Barrel shifts (one per group pair per output element).
    pub shifts: u64,
}

pub fn count_ops(m: usize, n: usize, k: usize, group: usize) -> GemmOpCount {
    let gpr = k.div_ceil(group) as u64;
    GemmOpCount {
        narrow_mults: (m * n * k) as u64,
        adds: (m * n * k) as u64 + (m * n) as u64 * gpr,
        shifts: (m * n) as u64 * gpr,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::{Granularity, QuantTensor};
    use crate::sdr::razor::SdrSpec;
    use crate::util::quickcheck::{check, Config, IntRange, PairGen};
    use crate::util::rng::Rng;

    fn make_pair(
        m: usize,
        n: usize,
        k: usize,
        g: usize,
        act_target: u32,
        seed: u64,
    ) -> (SdrMatrix, SdrMatrix) {
        let mut rng = Rng::new(seed);
        let mut x = Tensor::zeros(&[m, k]);
        for v in x.data_mut().iter_mut() {
            *v = rng.heavy_tailed(1.0, 0.02, 20.0);
        }
        let mut wt = Tensor::zeros(&[n, k]);
        for v in wt.data_mut().iter_mut() {
            *v = rng.normal_f32(0.0, 0.05);
        }
        let qa = QuantTensor::quantize(&x, 16, Granularity::PerTensor);
        let qw = QuantTensor::quantize(&wt, 8, Granularity::PerChannel);
        (
            SdrMatrix::compress(SdrSpec::new(16, act_target, g), &qa),
            SdrMatrix::compress(SdrSpec::new(8, 4, g), &qw),
        )
    }

    #[test]
    fn razored_equals_decompressed_small() {
        let (a, w) = make_pair(3, 5, 32, 8, 4, 1);
        assert_eq!(gemm_razored_int(&a, &w).data(), gemm_decompress(&a, &w).data());
    }

    #[test]
    fn razored_equals_decompressed_w4a8() {
        let (a, w) = make_pair(4, 4, 64, 16, 8, 2);
        assert_eq!(gemm_razored_int(&a, &w).data(), gemm_decompress(&a, &w).data());
    }

    #[test]
    fn ragged_tail_group_handled() {
        // k=50 with g=16 leaves a ragged final group of 2.
        let (a, w) = make_pair(2, 3, 50, 16, 4, 3);
        assert_eq!(gemm_razored_int(&a, &w).data(), gemm_decompress(&a, &w).data());
    }

    #[test]
    fn prop_decompression_free_equals_decompressed() {
        // The paper's §4.3 equivalence as a property over sizes/groups.
        let gen = PairGen(IntRange { lo: 1, hi: 6 }, IntRange { lo: 1, hi: 48 });
        let cfg = Config { cases: 60, ..Default::default() };
        check("razored≡decompressed", cfg, &gen, |&(mn, k)| {
            let (m, n, k) = (mn as usize, (mn as usize % 3) + 1, k as usize);
            for g in [4usize, 16, 32] {
                let (a, w) = make_pair(m, n, k, g, 4, (m * 1000 + k) as u64);
                if gemm_razored_int(&a, &w).data() != gemm_decompress(&a, &w).data() {
                    return false;
                }
            }
            true
        });
    }

    #[test]
    fn float_output_approximates_reference_matmul() {
        // End-to-end: quant → SDR → razored GEMM ≈ f32 matmul with modest
        // relative error on well-conditioned data.
        let mut rng = Rng::new(5);
        let (m, n, k) = (8, 8, 256);
        let mut x = Tensor::zeros(&[m, k]);
        rng.fill_normal(x.data_mut(), 0.0, 1.0);
        let mut wt = Tensor::zeros(&[n, k]);
        rng.fill_normal(wt.data_mut(), 0.0, 0.05);
        let qa = QuantTensor::quantize(&x, 16, Granularity::PerTensor);
        let qw = QuantTensor::quantize(&wt, 8, Granularity::PerChannel);
        let a = SdrMatrix::compress(SdrSpec::new(16, 4, 16), &qa);
        let w = SdrMatrix::compress(SdrSpec::new(8, 4, 16), &qw);
        let c = gemm_razored(&a, &w);
        let c_ref = crate::tensor::matmul_bt(&x, &wt);
        let mut num = 0f64;
        let mut den = 0f64;
        for (a, b) in c.data().iter().zip(c_ref.data()) {
            num += ((a - b) as f64).powi(2);
            den += (*b as f64).powi(2);
        }
        let rel = (num / den).sqrt();
        assert!(rel < 0.2, "relative error {rel}");
    }

    #[test]
    fn per_channel_weight_scales_applied() {
        // Two weight rows identical up to scale; outputs must scale too.
        let x = Tensor::from_vec(&[1, 4], vec![1.0, 2.0, 3.0, 4.0]);
        let wt = Tensor::from_vec(&[2, 4], vec![0.1, 0.2, 0.3, 0.4, 1.0, 2.0, 3.0, 4.0]);
        let qa = QuantTensor::quantize(&x, 16, Granularity::PerTensor);
        let qw = QuantTensor::quantize(&wt, 8, Granularity::PerChannel);
        let a = SdrMatrix::compress(SdrSpec::new(16, 4, 4), &qa);
        let w = SdrMatrix::compress(SdrSpec::new(8, 4, 4), &qw);
        let c = gemm_razored(&a, &w);
        let ratio = c.data()[1] / c.data()[0];
        assert!((ratio - 10.0).abs() < 0.2, "ratio {ratio}");
    }

    #[test]
    fn apply_scales_uses_per_row_activation_scales() {
        // Two activation rows identical up to 10×, quantized PER-CHANNEL:
        // their codes coincide and only the stage-1 scales differ, so the
        // GEMM outputs must differ by exactly that factor. The old
        // `scale_for_row(0)` shortcut collapsed the ratio to 1.
        let x = Tensor::from_vec(&[2, 4], vec![1.0, 2.0, 3.0, 4.0, 10.0, 20.0, 30.0, 40.0]);
        let wt = Tensor::from_vec(&[1, 4], vec![0.3, -0.1, 0.2, 0.5]);
        let qa = QuantTensor::quantize(&x, 16, Granularity::PerChannel);
        let qw = QuantTensor::quantize(&wt, 8, Granularity::PerChannel);
        let a = SdrMatrix::compress(SdrSpec::new(16, 4, 4), &qa);
        let w = SdrMatrix::compress(SdrSpec::new(8, 4, 4), &qw);
        assert_eq!(a.scales.len(), 2);
        assert!((a.scales[1] / a.scales[0] - 10.0).abs() < 1e-4);
        let c = gemm_razored(&a, &w);
        let ratio = c.data()[1] / c.data()[0];
        assert!((ratio - 10.0).abs() < 1e-3, "activation row scale dropped: ratio {ratio}");
        // and the packed path agrees bit-for-bit
        let cp = gemm_razored_packed_f32(
            &crate::sdr::packed::PackedSdrMatrix::from_matrix(&a),
            &crate::sdr::packed::PackedSdrMatrix::from_matrix(&w),
        );
        assert_eq!(c.data(), cp.data());
    }

    #[test]
    fn packed_equals_unpacked_small() {
        let (a, w) = make_pair(3, 5, 32, 8, 4, 17);
        let (pa, pw) = (
            crate::sdr::packed::PackedSdrMatrix::from_matrix(&a),
            crate::sdr::packed::PackedSdrMatrix::from_matrix(&w),
        );
        assert_eq!(gemm_razored_packed(&pa, &pw).data(), gemm_razored_int(&a, &w).data());
    }

    #[test]
    fn packed_handles_ragged_and_blocked_shapes() {
        // Shapes straddling every blocking boundary: row blocks (8),
        // ragged tail groups, odd nibble counts.
        for (m, n, k, g) in [
            (1usize, 1usize, 1usize, 4usize),
            (2, 3, 37, 8),      // odd cols, ragged tail
            (9, 33, 50, 16),    // one past both block sizes
            (8, 32, 64, 16),    // exactly on block boundaries
            (17, 5, 127, 128),  // single ragged group per row, max tile
        ] {
            let (a, w) = make_pair(m, n, k, g, 4, (m * 31 + n * 7 + k) as u64);
            let (pa, pw) = (
                crate::sdr::packed::PackedSdrMatrix::from_matrix(&a),
                crate::sdr::packed::PackedSdrMatrix::from_matrix(&w),
            );
            let packed = gemm_razored_packed(&pa, &pw);
            let unpacked = gemm_razored_int(&a, &w);
            let reference = gemm_decompress(&a, &w);
            assert_eq!(packed.data(), unpacked.data(), "{m}x{n}x{k} g{g}");
            assert_eq!(packed.data(), reference.data(), "{m}x{n}x{k} g{g}");
        }
    }

    #[test]
    fn prop_packed_equals_unpacked_equals_decompressed() {
        // The tentpole invariant: the nibble-walking kernel, the unpacked
        // kernel and the decompress-then-multiply reference agree bit for
        // bit on every shape/group, including all-negative inputs.
        let gen = PairGen(IntRange { lo: 1, hi: 20 }, IntRange { lo: 1, hi: 70 });
        let cfg = Config { cases: 40, ..Default::default() };
        check("packed≡unpacked≡decompressed", cfg, &gen, |&(mn, k)| {
            let (m, n, k) = (mn as usize, ((mn as usize * 5) % 37) + 1, k as usize);
            for g in [4usize, 16, 128] {
                let (a, w) = make_pair(m, n, k, g, 4, (m * 1009 + n * 13 + k) as u64);
                let (pa, pw) = (
                    crate::sdr::packed::PackedSdrMatrix::from_matrix(&a),
                    crate::sdr::packed::PackedSdrMatrix::from_matrix(&w),
                );
                let packed = gemm_razored_packed(&pa, &pw);
                if packed.data() != gemm_razored_int(&a, &w).data()
                    || packed.data() != gemm_decompress(&a, &w).data()
                {
                    return false;
                }
            }
            true
        });
    }

    #[test]
    fn packed_all_negative_matrix() {
        let x = Tensor::from_vec(&[2, 8], vec![-1.0f32; 16]);
        let wt = Tensor::from_vec(&[2, 8], vec![-0.5f32; 16]);
        let qa = QuantTensor::quantize(&x, 16, Granularity::PerTensor);
        let qw = QuantTensor::quantize(&wt, 8, Granularity::PerChannel);
        let a = SdrMatrix::compress(SdrSpec::new(16, 4, 4), &qa);
        let w = SdrMatrix::compress(SdrSpec::new(8, 4, 4), &qw);
        let (pa, pw) = (
            crate::sdr::packed::PackedSdrMatrix::from_matrix(&a),
            crate::sdr::packed::PackedSdrMatrix::from_matrix(&w),
        );
        assert_eq!(gemm_razored_packed(&pa, &pw).data(), gemm_decompress(&a, &w).data());
        // (−)·(−) must come out positive through the packed sign path
        assert!(gemm_razored_packed(&pa, &pw).data().iter().all(|&v| v > 0));
    }

    #[test]
    fn a8_packed_equals_unpacked_small() {
        let (a, w) = make_pair(3, 5, 32, 8, 8, 21);
        let (ba, pw) = (
            crate::sdr::packed::ByteSdrMatrix::from_matrix(&a),
            crate::sdr::packed::PackedSdrMatrix::from_matrix(&w),
        );
        assert_eq!(gemm_razored_packed_a8(&ba, &pw).data(), gemm_razored_int(&a, &w).data());
    }

    #[test]
    fn prop_a8_packed_equals_staged_reference() {
        // The W4A8 operand satellite: byte-coded activations against
        // nibble-packed weights must match the unpacked razored GEMM
        // and the decompress-then-multiply reference bit for bit on
        // every shape/group — the same lattice the staged fake-quant
        // path computes on.
        let gen = PairGen(IntRange { lo: 1, hi: 20 }, IntRange { lo: 1, hi: 70 });
        let cfg = Config { cases: 40, ..Default::default() };
        check("a8-packed≡staged", cfg, &gen, |&(mn, k)| {
            let (m, n, k) = (mn as usize, ((mn as usize * 5) % 37) + 1, k as usize);
            for g in [4usize, 16, 128] {
                let (a, w) = make_pair(m, n, k, g, 8, (m * 733 + n * 17 + k) as u64);
                let (ba, pw) = (
                    crate::sdr::packed::ByteSdrMatrix::from_matrix(&a),
                    crate::sdr::packed::PackedSdrMatrix::from_matrix(&w),
                );
                let packed = gemm_razored_packed_a8(&ba, &pw);
                if packed.data() != gemm_razored_int(&a, &w).data()
                    || packed.data() != gemm_decompress(&a, &w).data()
                {
                    return false;
                }
            }
            true
        });
    }

    #[test]
    fn a8_operand_moves_twice_the_a4_bytes() {
        // The cost asymmetry the speculative draft exploits: the A8
        // basis operand streams ~2x the code bytes of the razored A4
        // form of the same activation.
        let (a4, _) = make_pair(8, 1, 128, 16, 4, 5);
        let (a8, _) = make_pair(8, 1, 128, 16, 8, 5);
        let p4 = crate::sdr::packed::PackedSdrMatrix::from_matrix(&a4);
        let b8 = crate::sdr::packed::ByteSdrMatrix::from_matrix(&a8);
        let ratio = b8.payload_bytes() as f64 / p4.payload_bytes() as f64;
        assert!((1.8..=2.1).contains(&ratio), "A8/A4 operand ratio {ratio}");
    }

    #[test]
    fn op_count_formulae() {
        let ops = count_ops(128, 64, 512, 32);
        assert_eq!(ops.narrow_mults, 128 * 64 * 512);
        assert_eq!(ops.shifts, 128 * 64 * (512 / 32));
        assert_eq!(ops.adds, 128 * 64 * 512 + 128 * 64 * 16);
    }

    #[test]
    fn zero_activation_rows_give_zero_output() {
        let x = Tensor::zeros(&[2, 32]);
        let mut rng = Rng::new(8);
        let mut wt = Tensor::zeros(&[3, 32]);
        rng.fill_normal(wt.data_mut(), 0.0, 1.0);
        let qa = QuantTensor::quantize(&x, 16, Granularity::PerTensor);
        let qw = QuantTensor::quantize(&wt, 8, Granularity::PerChannel);
        let a = SdrMatrix::compress(SdrSpec::new(16, 4, 16), &qa);
        let w = SdrMatrix::compress(SdrSpec::new(8, 4, 16), &qw);
        assert!(gemm_razored(&a, &w).data().iter().all(|&v| v == 0.0));
    }
}
