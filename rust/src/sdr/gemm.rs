//! Decompression-free integer GEMM (paper §4.3, Fig. 3(b)).
//!
//! `C = A · Wᵀ` where A is an SDR-compressed activation matrix
//! `[m, k]` (per-tensor scale, groups along k) and W an SDR-compressed
//! weight matrix `[n, k]` (per-channel scales, groups along k). Both
//! share the same group size so group boundaries align.
//!
//! Per output element the datapath is exactly the paper's: for each
//! group pair `p`, narrow multiplies `s_a·s_w` of the salient codes
//! (4×4-bit for W4A4 — an 8-bit product), sign via XOR, a *group-local*
//! accumulation, then **one** barrel shift by `flag_a(p) + flag_w(p)`
//! into the wide accumulator. No element is ever reconstructed to base
//! precision. `gemm_decompress` implements Fig. 3(a) — reconstruct both
//! operands, multiply at base precision — and the two are bit-identical
//! (`prop_decompression_free_equals_decompressed`), which is the claim
//! that makes the paper's hardware unit sound.

use super::razor::SdrMatrix;
use crate::tensor::Tensor;
use crate::util::threadpool::parallel_for;

/// Decompression-free GEMM: returns the float result
/// `C[i,j] = scale_a · scale_w[j] · Σ_p ((Σ_{t∈p} sa·sw) << (fa_p + fw_p))`.
pub fn gemm_razored(a: &SdrMatrix, w: &SdrMatrix) -> Tensor<f32> {
    let acc = gemm_razored_int(a, w);
    apply_scales(&acc, a, w)
}

/// Integer part of the decompression-free GEMM (pre-scale accumulators).
///
/// Perf note (§Perf in EXPERIMENTS.md): the sign-magnitude [`SdrCode`]
/// struct is the *storage* format; multiplying through it costs a
/// branchy conversion per MAC. We materialize each operand's signed
/// salient codes once as flat `i16` arrays — an O(mk + nk) pass
/// amortized over the O(mnk) MACs — which matches the hardware exactly
/// (the 4×4 multiplier consumes the code lines directly; sign is an
/// XOR) and lets the inner loop autovectorize.
pub fn gemm_razored_int(a: &SdrMatrix, w: &SdrMatrix) -> Tensor<i64> {
    assert_eq!(a.cols, w.cols, "reduction dims differ: {} vs {}", a.cols, w.cols);
    assert_eq!(a.spec.group, w.spec.group, "group sizes must align");
    let (m, n, k) = (a.rows, w.rows, a.cols);
    let g = a.spec.group;
    let gpr = a.groups_per_row();
    let mut c: Tensor<i64> = Tensor::zeros(&[m, n]);

    let a_signed: Vec<i16> = a.codes.iter().map(|c| c.signed() as i16).collect();
    let w_signed: Vec<i16> = w.codes.iter().map(|c| c.signed() as i16).collect();

    struct SendPtr(*mut i64);
    unsafe impl Sync for SendPtr {}
    impl SendPtr {
        fn get(&self) -> *mut i64 {
            self.0
        }
    }
    let cptr = SendPtr(c.data_mut().as_mut_ptr());

    parallel_for(m, |i| {
        let arow = &a_signed[i * k..(i + 1) * k];
        let aflags = a.row_flags(i);
        let crow = unsafe { std::slice::from_raw_parts_mut(cptr.get().add(i * n), n) };
        for (j, cj) in crow.iter_mut().enumerate() {
            let wrow = &w_signed[j * k..(j + 1) * k];
            let wflags = w.row_flags(j);
            let mut acc: i64 = 0;
            for p in 0..gpr {
                let lo = p * g;
                let hi = (lo + g).min(k);
                // Group-local narrow MAC: products fit easily in i32
                // (≤ 7·7·g for W4A4; ≤ 127·127·g for the A8 ablation).
                let mut part: i32 = 0;
                for (&x, &y) in arow[lo..hi].iter().zip(&wrow[lo..hi]) {
                    part += (x as i32) * (y as i32);
                }
                // One barrel shift per group pair (the Fig. 3(b) shifter).
                acc += (part as i64) << (aflags[p] + wflags[p]);
            }
            *cj = acc;
        }
    });
    c
}

/// Fig. 3(a) reference: reconstruct both operands to base precision and
/// multiply at full width. Used only to prove equivalence.
pub fn gemm_decompress(a: &SdrMatrix, w: &SdrMatrix) -> Tensor<i64> {
    let ar = a.reconstruct();
    let wr = w.reconstruct();
    let (m, n, k) = (a.rows, w.rows, a.cols);
    let mut c: Tensor<i64> = Tensor::zeros(&[m, n]);
    for i in 0..m {
        for j in 0..n {
            let mut acc: i64 = 0;
            for t in 0..k {
                acc += ar.values[i * k + t] as i64 * wr.values[j * k + t] as i64;
            }
            c.data_mut()[i * n + j] = acc;
        }
    }
    c
}

/// Turn integer accumulators into floats with the stage-1 scales.
pub fn apply_scales(acc: &Tensor<i64>, a: &SdrMatrix, w: &SdrMatrix) -> Tensor<f32> {
    let (m, n) = (acc.shape()[0], acc.shape()[1]);
    let sa = a.scale_for_row(0); // activations are per-tensor
    let mut out = Tensor::zeros(&[m, n]);
    for i in 0..m {
        for j in 0..n {
            out.data_mut()[i * n + j] =
                acc.data()[i * n + j] as f32 * sa * w.scale_for_row(j);
        }
    }
    out
}

/// Operation counts of one razored GEMM — feeds `crate::hw::opcount`
/// and the Table 8 bench.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct GemmOpCount {
    /// Narrow (4×4 or 8×8) integer multiplies.
    pub narrow_mults: u64,
    /// Group-local integer adds.
    pub adds: u64,
    /// Barrel shifts (one per group pair per output element).
    pub shifts: u64,
}

pub fn count_ops(m: usize, n: usize, k: usize, group: usize) -> GemmOpCount {
    let gpr = k.div_ceil(group) as u64;
    GemmOpCount {
        narrow_mults: (m * n * k) as u64,
        adds: (m * n * k) as u64 + (m * n) as u64 * gpr,
        shifts: (m * n) as u64 * gpr,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::{Granularity, QuantTensor};
    use crate::sdr::razor::SdrSpec;
    use crate::util::quickcheck::{check, Config, IntRange, PairGen};
    use crate::util::rng::Rng;

    fn make_pair(
        m: usize,
        n: usize,
        k: usize,
        g: usize,
        act_target: u32,
        seed: u64,
    ) -> (SdrMatrix, SdrMatrix) {
        let mut rng = Rng::new(seed);
        let mut x = Tensor::zeros(&[m, k]);
        for v in x.data_mut().iter_mut() {
            *v = rng.heavy_tailed(1.0, 0.02, 20.0);
        }
        let mut wt = Tensor::zeros(&[n, k]);
        for v in wt.data_mut().iter_mut() {
            *v = rng.normal_f32(0.0, 0.05);
        }
        let qa = QuantTensor::quantize(&x, 16, Granularity::PerTensor);
        let qw = QuantTensor::quantize(&wt, 8, Granularity::PerChannel);
        (
            SdrMatrix::compress(SdrSpec::new(16, act_target, g), &qa),
            SdrMatrix::compress(SdrSpec::new(8, 4, g), &qw),
        )
    }

    #[test]
    fn razored_equals_decompressed_small() {
        let (a, w) = make_pair(3, 5, 32, 8, 4, 1);
        assert_eq!(gemm_razored_int(&a, &w).data(), gemm_decompress(&a, &w).data());
    }

    #[test]
    fn razored_equals_decompressed_w4a8() {
        let (a, w) = make_pair(4, 4, 64, 16, 8, 2);
        assert_eq!(gemm_razored_int(&a, &w).data(), gemm_decompress(&a, &w).data());
    }

    #[test]
    fn ragged_tail_group_handled() {
        // k=50 with g=16 leaves a ragged final group of 2.
        let (a, w) = make_pair(2, 3, 50, 16, 4, 3);
        assert_eq!(gemm_razored_int(&a, &w).data(), gemm_decompress(&a, &w).data());
    }

    #[test]
    fn prop_decompression_free_equals_decompressed() {
        // The paper's §4.3 equivalence as a property over sizes/groups.
        let gen = PairGen(IntRange { lo: 1, hi: 6 }, IntRange { lo: 1, hi: 48 });
        check("razored≡decompressed", Config { cases: 60, ..Default::default() }, &gen, |&(mn, k)| {
            let (m, n, k) = (mn as usize, (mn as usize % 3) + 1, k as usize);
            for g in [4usize, 16, 32] {
                let (a, w) = make_pair(m, n, k, g, 4, (m * 1000 + k) as u64);
                if gemm_razored_int(&a, &w).data() != gemm_decompress(&a, &w).data() {
                    return false;
                }
            }
            true
        });
    }

    #[test]
    fn float_output_approximates_reference_matmul() {
        // End-to-end: quant → SDR → razored GEMM ≈ f32 matmul with modest
        // relative error on well-conditioned data.
        let mut rng = Rng::new(5);
        let (m, n, k) = (8, 8, 256);
        let mut x = Tensor::zeros(&[m, k]);
        rng.fill_normal(x.data_mut(), 0.0, 1.0);
        let mut wt = Tensor::zeros(&[n, k]);
        rng.fill_normal(wt.data_mut(), 0.0, 0.05);
        let qa = QuantTensor::quantize(&x, 16, Granularity::PerTensor);
        let qw = QuantTensor::quantize(&wt, 8, Granularity::PerChannel);
        let a = SdrMatrix::compress(SdrSpec::new(16, 4, 16), &qa);
        let w = SdrMatrix::compress(SdrSpec::new(8, 4, 16), &qw);
        let c = gemm_razored(&a, &w);
        let c_ref = crate::tensor::matmul_bt(&x, &wt);
        let mut num = 0f64;
        let mut den = 0f64;
        for (a, b) in c.data().iter().zip(c_ref.data()) {
            num += ((a - b) as f64).powi(2);
            den += (*b as f64).powi(2);
        }
        let rel = (num / den).sqrt();
        assert!(rel < 0.2, "relative error {rel}");
    }

    #[test]
    fn per_channel_weight_scales_applied() {
        // Two weight rows identical up to scale; outputs must scale too.
        let x = Tensor::from_vec(&[1, 4], vec![1.0, 2.0, 3.0, 4.0]);
        let wt = Tensor::from_vec(&[2, 4], vec![0.1, 0.2, 0.3, 0.4, 1.0, 2.0, 3.0, 4.0]);
        let qa = QuantTensor::quantize(&x, 16, Granularity::PerTensor);
        let qw = QuantTensor::quantize(&wt, 8, Granularity::PerChannel);
        let a = SdrMatrix::compress(SdrSpec::new(16, 4, 4), &qa);
        let w = SdrMatrix::compress(SdrSpec::new(8, 4, 4), &qw);
        let c = gemm_razored(&a, &w);
        let ratio = c.data()[1] / c.data()[0];
        assert!((ratio - 10.0).abs() < 0.2, "ratio {ratio}");
    }

    #[test]
    fn op_count_formulae() {
        let ops = count_ops(128, 64, 512, 32);
        assert_eq!(ops.narrow_mults, 128 * 64 * 512);
        assert_eq!(ops.shifts, 128 * 64 * (512 / 32));
        assert_eq!(ops.adds, 128 * 64 * 512 + 128 * 64 * 16);
    }

    #[test]
    fn zero_activation_rows_give_zero_output() {
        let x = Tensor::zeros(&[2, 32]);
        let mut rng = Rng::new(8);
        let mut wt = Tensor::zeros(&[3, 32]);
        rng.fill_normal(wt.data_mut(), 0.0, 1.0);
        let qa = QuantTensor::quantize(&x, 16, Granularity::PerTensor);
        let qw = QuantTensor::quantize(&wt, 8, Granularity::PerChannel);
        let a = SdrMatrix::compress(SdrSpec::new(16, 4, 16), &qa);
        let w = SdrMatrix::compress(SdrSpec::new(8, 4, 16), &qw);
        assert!(gemm_razored(&a, &w).data().iter().all(|&v| v == 0.0));
    }
}
