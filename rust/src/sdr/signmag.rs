//! Sign-magnitude arithmetic helpers (paper Algorithm 1's format step).
//!
//! The quantization stage produces symmetric two's-complement integers;
//! SDR operates on *sign-and-magnitude*: a sign bit plus an unsigned
//! magnitude. The conversion is trivial in software but spelled out here
//! because the hardware datapath (`crate::hw::datapath`) mirrors these
//! exact bit manipulations and the tests cross-check both.

/// Sign-magnitude decomposition of a quantized value.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct SignMag {
    /// true = negative.
    pub neg: bool,
    pub mag: u32,
}

impl SignMag {
    #[inline]
    pub fn from_i32(v: i32) -> SignMag {
        SignMag { neg: v < 0, mag: v.unsigned_abs() }
    }

    #[inline]
    pub fn to_i32(self) -> i32 {
        if self.neg {
            -(self.mag as i32)
        } else {
            self.mag as i32
        }
    }

    /// Encode into a `bits`-wide field: sign in the MSB, magnitude below.
    /// This is the wire format of the packed stores.
    #[inline]
    pub fn encode(self, bits: u32) -> u32 {
        debug_assert!(self.mag < (1 << (bits - 1)), "mag {} overflows {bits} bits", self.mag);
        ((self.neg as u32) << (bits - 1)) | self.mag
    }

    #[inline]
    pub fn decode(field: u32, bits: u32) -> SignMag {
        let sign_bit = 1u32 << (bits - 1);
        SignMag { neg: field & sign_bit != 0, mag: field & (sign_bit - 1) }
    }
}

/// Bit position (0-indexed from LSB) of the leading one; `None` for 0.
#[inline]
pub fn leading_one(v: u32) -> Option<u32> {
    if v == 0 {
        None
    } else {
        Some(31 - v.leading_zeros())
    }
}

/// Bitwise OR of all magnitudes in a slice of quantized values — the
/// paper's one-pass group statistic (Appendix A.2): the leading one of
/// the OR equals the max of the leading ones, obtained without comparing
/// magnitudes.
#[inline]
pub fn group_or(values: &[i32]) -> u32 {
    let mut acc = 0u32;
    for &v in values {
        acc |= v.unsigned_abs();
    }
    acc
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::quickcheck::{check, Config, IntRange, VecGen};

    #[test]
    fn roundtrip_i32() {
        for v in [-32767i32, -1, 0, 1, 5, 127, 32767] {
            assert_eq!(SignMag::from_i32(v).to_i32(), v);
        }
    }

    #[test]
    fn encode_decode_field() {
        let sm = SignMag { neg: true, mag: 5 };
        let f = sm.encode(4);
        assert_eq!(f, 0b1101);
        assert_eq!(SignMag::decode(f, 4), sm);
        let sm2 = SignMag { neg: false, mag: 7 };
        assert_eq!(SignMag::decode(sm2.encode(4), 4), sm2);
    }

    #[test]
    fn leading_one_positions() {
        assert_eq!(leading_one(0), None);
        assert_eq!(leading_one(1), Some(0));
        assert_eq!(leading_one(2), Some(1));
        assert_eq!(leading_one(3), Some(1));
        assert_eq!(leading_one(0x8000), Some(15));
        assert_eq!(leading_one(0x7FFF), Some(14));
    }

    #[test]
    fn group_or_handles_negatives() {
        assert_eq!(group_or(&[-5, 2]), 7);
        assert_eq!(group_or(&[0, 0]), 0);
        assert_eq!(group_or(&[-32767]), 32767);
    }

    #[test]
    fn prop_leading_one_of_or_is_max_of_leading_ones() {
        // The paper's core hardware claim (Appendix A.2): OR-then-LZD is
        // equivalent to max-of-LZDs. Property-check it.
        let gen = VecGen { elem: IntRange { lo: -32767, hi: 32767 }, min_len: 1, max_len: 128 };
        check("or-lzd-equiv", Config::default(), &gen, |xs| {
            let xs: Vec<i32> = xs.iter().map(|&x| x as i32).collect();
            let via_or = leading_one(group_or(&xs));
            let via_max = xs
                .iter()
                .filter_map(|&v| leading_one(v.unsigned_abs()))
                .max();
            via_or == via_max
        });
    }

    #[test]
    fn prop_signmag_roundtrip() {
        check(
            "signmag-roundtrip",
            Config::default(),
            &IntRange { lo: -(1 << 20), hi: 1 << 20 },
            |&v| SignMag::from_i32(v as i32).to_i32() == v as i32,
        );
    }
}
