//! Backing storage for packed SDR planes: owned heap bytes or a window
//! into a shared memory-mapped checkpoint.
//!
//! [`PlaneStore`] is what [`super::packed::PackedSdrMatrix`] and
//! [`super::packed::ByteSdrMatrix`] hold their nibble/code/flag planes
//! in. In-process quantization produces `Owned` planes (exactly the old
//! `Vec<u8>` behavior — `From<Vec<u8>>` keeps every construction site a
//! one-word change), while the artifact loader (`crate::artifact`)
//! produces `Mapped` windows into one `Arc<Mmap>` per checkpoint file:
//! zero-copy, demand-paged by the OS, and shared across every linear,
//! shard, and clone. All consumers read through `Deref<Target = [u8]>`,
//! so the GEMM/attention kernels are byte-identical over either
//! backing.

use std::sync::Arc;

use crate::util::mmap::Mmap;

#[derive(Clone)]
enum Backing {
    Owned(Vec<u8>),
    Mapped { map: Arc<Mmap>, offset: usize, len: usize },
}

/// An immutable byte plane: owned, or a window of a shared mapping.
/// Clone is cheap for mapped planes (one `Arc` bump) and a deep copy
/// for owned ones — matching the pre-refactor `Vec<u8>` semantics.
#[derive(Clone)]
pub struct PlaneStore {
    backing: Backing,
}

impl PlaneStore {
    /// A window `[offset, offset + len)` of a shared mapping. Bounds
    /// are checked once here so `as_slice` never can't.
    pub fn mapped(map: Arc<Mmap>, offset: usize, len: usize) -> PlaneStore {
        assert!(
            offset.checked_add(len).is_some_and(|end| end <= map.len()),
            "plane window {offset}+{len} exceeds mapping of {} bytes",
            map.len()
        );
        PlaneStore { backing: Backing::Mapped { map, offset, len } }
    }

    pub fn as_slice(&self) -> &[u8] {
        match &self.backing {
            Backing::Owned(v) => v,
            Backing::Mapped { map, offset, len } => &map.as_slice()[*offset..*offset + *len],
        }
    }

    /// Is this plane a window into a mapped checkpoint (true) or an
    /// owned heap buffer (false)?
    pub fn is_mapped(&self) -> bool {
        matches!(self.backing, Backing::Mapped { .. })
    }

    pub fn len(&self) -> usize {
        match &self.backing {
            Backing::Owned(v) => v.len(),
            Backing::Mapped { len, .. } => *len,
        }
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Copy out to an owned buffer (mapped planes detach from the map).
    pub fn to_vec(&self) -> Vec<u8> {
        self.as_slice().to_vec()
    }
}

impl From<Vec<u8>> for PlaneStore {
    fn from(v: Vec<u8>) -> PlaneStore {
        PlaneStore { backing: Backing::Owned(v) }
    }
}

impl std::ops::Deref for PlaneStore {
    type Target = [u8];
    fn deref(&self) -> &[u8] {
        self.as_slice()
    }
}

impl<'a> IntoIterator for &'a PlaneStore {
    type Item = &'a u8;
    type IntoIter = std::slice::Iter<'a, u8>;
    fn into_iter(self) -> Self::IntoIter {
        self.as_slice().iter()
    }
}

impl PartialEq for PlaneStore {
    fn eq(&self, other: &PlaneStore) -> bool {
        self.as_slice() == other.as_slice()
    }
}

impl Eq for PlaneStore {}

impl std::fmt::Debug for PlaneStore {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        let kind = if self.is_mapped() { "mapped" } else { "owned" };
        write!(f, "PlaneStore({kind}, {} bytes)", self.len())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn owned_roundtrip_and_deref() {
        let p: PlaneStore = vec![1u8, 2, 3].into();
        assert!(!p.is_mapped());
        assert_eq!(p.len(), 3);
        assert_eq!(&p[..], &[1, 2, 3]);
        assert_eq!(p.iter().copied().sum::<u8>(), 6);
        assert_eq!(p.to_vec(), vec![1, 2, 3]);
    }

    #[test]
    fn mapped_window_reads_through_shared_map() {
        let dir = std::env::temp_dir().join("qrazor_test_store");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join(format!("window_{}", std::process::id()));
        std::fs::write(&path, (0..64u8).collect::<Vec<u8>>()).unwrap();
        let map = Arc::new(Mmap::open(&path).unwrap());
        let a = PlaneStore::mapped(Arc::clone(&map), 8, 4);
        let b = PlaneStore::mapped(Arc::clone(&map), 12, 4);
        assert!(a.is_mapped());
        assert_eq!(&a[..], &[8, 9, 10, 11]);
        assert_eq!(&b[..], &[12, 13, 14, 15]);
        // clones share the same mapping, not copies of it
        let c = a.clone();
        assert_eq!(Arc::strong_count(&map), 4);
        assert_eq!(&c[..], &a[..]);
        // equality is by bytes, across backings
        let owned: PlaneStore = vec![8u8, 9, 10, 11].into();
        assert_eq!(a, owned);
        std::fs::remove_file(&path).ok();
    }

    #[test]
    #[should_panic(expected = "exceeds mapping")]
    fn out_of_bounds_window_is_rejected_at_construction() {
        let dir = std::env::temp_dir().join("qrazor_test_store");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join(format!("oob_{}", std::process::id()));
        std::fs::write(&path, vec![0u8; 16]).unwrap();
        let map = Arc::new(Mmap::open(&path).unwrap());
        std::fs::remove_file(&path).ok();
        PlaneStore::mapped(map, 10, 10);
    }
}
