//! The SDR coder (paper §4.2, Algorithm 1).
//!
//! Compression of one group of base-precision integers:
//!
//! ```text
//! magnitudes   m_i  (base_bits−1 wide)
//! group OR     M = m_0 | m_1 | … | m_{g−1}
//! razor point  r = leading-one index of M
//! flag         f = max(r − (s−1), 0)         s = target_bits−1 salient bits
//! code         c_i = rtn(m_i >> f)           floor when c_i would be all-ones
//! ```
//!
//! Reconstruction is `ĉ_i = c_i << f` with the original sign. The flag is
//! shared by the whole group; `target_bits` is all an element costs, so
//! effective storage is `target_bits + flag_bits/g` bits per value — the
//! paper's Eff. Bits column (g16 → 4.25, g32 → 4.125, g128 → 4.03).

use super::signmag::{group_or, leading_one};
use crate::quant::{Granularity, QuantTensor};

/// Static description of an SDR configuration.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct SdrSpec {
    /// Base precision (bits incl. sign) of the input integers: 8 or 16.
    pub base_bits: u32,
    /// Compressed precision (bits incl. sign): 4 (W4/A4/KV4) or 8 (A8).
    pub target_bits: u32,
    /// Elements per compression group (paper evaluates 8..128).
    pub group: usize,
}

impl SdrSpec {
    pub fn new(base_bits: u32, target_bits: u32, group: usize) -> SdrSpec {
        assert!(base_bits >= target_bits, "base {base_bits} < target {target_bits}");
        assert!((2..=16).contains(&target_bits));
        assert!(base_bits <= 16);
        assert!(group >= 1);
        SdrSpec { base_bits, target_bits, group }
    }

    /// Salient magnitude bits retained per element.
    #[inline]
    pub fn salient_bits(&self) -> u32 {
        self.target_bits - 1
    }

    /// Largest representable salient magnitude (all-ones code).
    #[inline]
    pub fn salient_max(&self) -> u32 {
        (1 << self.salient_bits()) - 1
    }

    /// Largest possible flag value: base magnitude width minus salient width.
    #[inline]
    pub fn max_flag(&self) -> u32 {
        (self.base_bits - 1).saturating_sub(self.salient_bits())
    }

    /// Bits used to store one flag. The paper stores 4 flag bits per
    /// group uniformly (Table 4's effective-bits arithmetic).
    #[inline]
    pub fn flag_bits(&self) -> u32 {
        4
    }

    /// Storage cost per element including amortized flags.
    pub fn effective_bits(&self) -> f64 {
        self.target_bits as f64 + self.flag_bits() as f64 / self.group as f64
    }
}

/// Compress the magnitudes of one group in place.
///
/// `values` are base-precision quantized integers (two's complement).
/// Returns the group flag and writes sign-preserved compressed codes
/// (`code` = salient magnitude, `neg` from input) through `out`.
#[inline]
pub fn compress_group(spec: &SdrSpec, values: &[i32], out: &mut [SdrCode]) -> u8 {
    debug_assert_eq!(values.len(), out.len());
    let m_or = group_or(values);
    let flag = match leading_one(m_or) {
        None => 0u32,
        Some(r) => r.saturating_sub(spec.salient_bits() - 1).min(spec.max_flag()),
    };
    let all_ones = spec.salient_max();
    for (o, &v) in out.iter_mut().zip(values) {
        let mag = v.unsigned_abs();
        let mut code = mag >> flag;
        // An input beyond the base precision (flag already capped at
        // max_flag) would overflow the salient width; saturate to the
        // all-ones code — same policy as stage-1's clamp — so no build
        // can ever hand the packer an aliasing >salient-width value.
        // In-range inputs are untouched.
        code = code.min(all_ones);
        // Round-to-nearest on the truncated LSBs — *unless* the code is
        // already all-ones, where a carry would overflow into the razor
        // window (Algorithm 1's floor exception).
        if code != all_ones && flag > 0 && (mag >> (flag - 1)) & 1 == 1 {
            code += 1;
        }
        *o = SdrCode { neg: v < 0, code: code as u8 };
    }
    // Numeric-health counting pass (one relaxed load when disabled):
    // zeroed codes, saturated codes, and the flag distribution for the
    // current (layer, site) scope.
    if crate::obs::health::health_enabled() {
        let (mut zeroed, mut saturated) = (0usize, 0usize);
        for (o, &v) in out.iter().zip(values) {
            if o.code == 0 {
                zeroed += 1;
            }
            if (v.unsigned_abs() >> flag) > all_ones {
                saturated += 1;
            }
        }
        crate::obs::health::note_razor_group(flag as u8, values.len(), zeroed, saturated);
    }
    flag as u8
}

/// One compressed element: sign + salient magnitude code.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Default)]
pub struct SdrCode {
    pub neg: bool,
    pub code: u8,
}

impl SdrCode {
    /// Reconstructed base-precision integer given the group flag.
    #[inline]
    pub fn reconstruct(self, flag: u8) -> i32 {
        let mag = (self.code as i32) << flag;
        if self.neg {
            -mag
        } else {
            mag
        }
    }

    /// Signed salient value in [−salient_max, +salient_max].
    #[inline]
    pub fn signed(self) -> i32 {
        if self.neg {
            -(self.code as i32)
        } else {
            self.code as i32
        }
    }
}

/// An SDR-compressed vector (one row / one tensor flattened): codes plus
/// per-group flags and the stage-1 scale needed for dequantization.
#[derive(Clone, Debug)]
pub struct SdrVector {
    pub spec: SdrSpec,
    pub codes: Vec<SdrCode>,
    pub flags: Vec<u8>,
    /// Stage-1 dequant multiplier (per-tensor or per-channel slice owner's).
    pub scale: f32,
}

impl SdrVector {
    /// Compress a slice of base-precision integers. The final group may
    /// be shorter than `spec.group` when the length is not divisible.
    pub fn compress(spec: SdrSpec, values: &[i32], scale: f32) -> SdrVector {
        let mut codes = vec![SdrCode::default(); values.len()];
        let mut flags = Vec::with_capacity(values.len().div_ceil(spec.group));
        for (chunk, out) in values.chunks(spec.group).zip(codes.chunks_mut(spec.group)) {
            flags.push(compress_group(&spec, chunk, out));
        }
        SdrVector { spec, codes, flags, scale }
    }

    pub fn len(&self) -> usize {
        self.codes.len()
    }

    pub fn is_empty(&self) -> bool {
        self.codes.is_empty()
    }

    /// Flag of the group containing element `i`.
    #[inline]
    pub fn flag_for(&self, i: usize) -> u8 {
        self.flags[i / self.spec.group]
    }

    /// Reconstruct base-precision integers (`decompress` in the paper).
    pub fn reconstruct(&self) -> Vec<i32> {
        self.codes
            .iter()
            .enumerate()
            .map(|(i, c)| c.reconstruct(self.flag_for(i)))
            .collect()
    }

    /// Dequantize straight to f32 (reconstruct × stage-1 scale).
    pub fn dequantize(&self) -> Vec<f32> {
        self.reconstruct().iter().map(|&v| v as f32 * self.scale).collect()
    }
}

/// A 2-D SDR-compressed matrix with row-major groups along the inner
/// (column / reduction) dimension — the layout both activations
/// `[tokens, channels]` and weights `[out_channels, in_channels]` use, so
/// GEMM group pairs align along k.
#[derive(Clone, Debug)]
pub struct SdrMatrix {
    pub spec: SdrSpec,
    pub rows: usize,
    pub cols: usize,
    pub codes: Vec<SdrCode>,
    /// `rows × groups_per_row` flags.
    pub flags: Vec<u8>,
    /// Per-row scale (len `rows`, per-channel weights) or single
    /// (len 1, per-tensor activations).
    pub scales: Vec<f32>,
}

impl SdrMatrix {
    pub fn groups_per_row(&self) -> usize {
        self.cols.div_ceil(self.spec.group)
    }

    /// Compress a stage-1 quantized tensor (2-D).
    pub fn compress(spec: SdrSpec, q: &QuantTensor) -> SdrMatrix {
        assert_eq!(q.shape.len(), 2, "SdrMatrix::compress needs 2-D");
        assert_eq!(
            q.bits, spec.base_bits,
            "stage-1 bits {} != spec.base_bits {}",
            q.bits, spec.base_bits
        );
        let (rows, cols) = (q.shape[0], q.shape[1]);
        let gpr = cols.div_ceil(spec.group);
        let mut codes = vec![SdrCode::default(); rows * cols];
        let mut flags = vec![0u8; rows * gpr];
        for r in 0..rows {
            let row = &q.values[r * cols..(r + 1) * cols];
            let orow = &mut codes[r * cols..(r + 1) * cols];
            for (gi, (chunk, out)) in row
                .chunks(spec.group)
                .zip(orow.chunks_mut(spec.group))
                .enumerate()
            {
                flags[r * gpr + gi] = compress_group(&spec, chunk, out);
            }
        }
        SdrMatrix { spec, rows, cols, codes, flags, scales: q.scales.clone() }
    }

    #[inline]
    pub fn scale_for_row(&self, r: usize) -> f32 {
        if self.scales.len() == 1 {
            self.scales[0]
        } else {
            self.scales[r]
        }
    }

    #[inline]
    pub fn row_codes(&self, r: usize) -> &[SdrCode] {
        &self.codes[r * self.cols..(r + 1) * self.cols]
    }

    #[inline]
    pub fn row_flags(&self, r: usize) -> &[u8] {
        let gpr = self.groups_per_row();
        &self.flags[r * gpr..(r + 1) * gpr]
    }

    /// Reconstruct to the base-precision integer lattice.
    pub fn reconstruct(&self) -> QuantTensor {
        let gpr = self.groups_per_row();
        let mut values = Vec::with_capacity(self.rows * self.cols);
        for r in 0..self.rows {
            for (i, c) in self.row_codes(r).iter().enumerate() {
                values.push(c.reconstruct(self.flags[r * gpr + i / self.spec.group]));
            }
        }
        QuantTensor {
            shape: vec![self.rows, self.cols],
            values,
            scales: self.scales.clone(),
            bits: self.spec.base_bits,
            granularity: if self.scales.len() == 1 {
                Granularity::PerTensor
            } else {
                Granularity::PerChannel
            },
        }
    }

    /// Dequantize to f32 (for the fake-quant accuracy experiments).
    pub fn dequantize(&self) -> crate::tensor::Tensor<f32> {
        self.reconstruct().dequantize()
    }

    /// Fraction of elements whose compressed code is zero — Fig. 2(c).
    pub fn zeroed_fraction(&self) -> f64 {
        if self.codes.is_empty() {
            return 0.0;
        }
        self.codes.iter().filter(|c| c.code == 0).count() as f64 / self.codes.len() as f64
    }
}

/// End-to-end fake-quant: stage-1 absmax quantization at `spec.base_bits`
/// then SDR compression and dequantization back to f32. This is *the*
/// QRazor transform every accuracy table applies.
pub fn qrazor_fake_quant(
    x: &crate::tensor::Tensor<f32>,
    spec: SdrSpec,
    granularity: Granularity,
) -> crate::tensor::Tensor<f32> {
    let q = QuantTensor::quantize(x, spec.base_bits, granularity);
    if x.ndim() == 2 {
        SdrMatrix::compress(spec, &q).dequantize()
    } else {
        let flat = QuantTensor { shape: vec![1, x.len()], ..q };
        let out = SdrMatrix::compress(spec, &flat).dequantize();
        crate::tensor::Tensor::from_vec(x.shape(), out.into_vec())
    }
}

/// Fake-quant with an externally calibrated static per-tensor scale
/// (the online activation path). Uses the fused no-allocation kernel
/// when the group fits the stack buffer.
pub fn qrazor_fake_quant_static(
    x: &crate::tensor::Tensor<f32>,
    spec: SdrSpec,
    scale: f32,
) -> crate::tensor::Tensor<f32> {
    if spec.group <= FUSED_MAX_GROUP {
        let mut out = crate::tensor::Tensor::zeros(x.shape());
        qrazor_fake_quant_slice(x.data(), spec, scale, out.data_mut());
        return out;
    }
    let q = QuantTensor::quantize_static(x, spec.base_bits, &[scale]);
    let flat = QuantTensor { shape: vec![1, x.len()], ..q };
    let out = SdrMatrix::compress(spec, &flat).dequantize();
    crate::tensor::Tensor::from_vec(x.shape(), out.into_vec())
}

/// Largest group the fused kernel's stack buffer covers (the paper
/// evaluates g ≤ 128).
pub const FUSED_MAX_GROUP: usize = 128;

/// Fused stage-1 + stage-2 + dequantize on a slice, no heap allocation
/// — the serving hot path (§Perf). Bit-identical to the staged
/// pipeline (property-tested below).
pub fn qrazor_fake_quant_slice(xs: &[f32], spec: SdrSpec, scale: f32, out: &mut [f32]) {
    assert_eq!(xs.len(), out.len());
    assert!(spec.group <= FUSED_MAX_GROUP, "group {} exceeds fused buffer", spec.group);
    let qm = crate::quant::qmax(spec.base_bits);
    let inv = if scale > 0.0 { 1.0 / scale } else { 0.0 };
    let sal = spec.salient_bits();
    let all_ones = spec.salient_max();
    let max_flag = spec.max_flag();
    let mut buf = [0i32; FUSED_MAX_GROUP];
    let track = crate::obs::health::health_enabled();
    for (chunk, ochunk) in xs.chunks(spec.group).zip(out.chunks_mut(spec.group)) {
        // stage 1 + group OR in one pass
        let mut m_or = 0u32;
        for (b, &x) in buf.iter_mut().zip(chunk) {
            let v = crate::quant::round_half_even(x * inv).clamp(-qm, qm);
            *b = v;
            m_or |= v.unsigned_abs();
        }
        let flag = match crate::sdr::signmag::leading_one(m_or) {
            None => 0u32,
            Some(r) => r.saturating_sub(sal - 1).min(max_flag),
        };
        // stage 2 + dequantize
        for (o, &v) in ochunk.iter_mut().zip(&buf) {
            let mag = v.unsigned_abs();
            let mut code = mag >> flag;
            if code != all_ones && flag > 0 && (mag >> (flag - 1)) & 1 == 1 {
                code += 1;
            }
            let rec = (code << flag) as f32 * scale;
            *o = if v < 0 { -rec } else { rec };
        }
        // Numeric-health counting pass. Stage 1 clamps to ±qm before
        // the group OR, so codes cannot saturate here — the saturation
        // signal on the fused path is the stage-1 clip count.
        if track {
            let (mut clipped, mut zeroed) = (0usize, 0usize);
            for (&v, &x) in buf.iter().zip(chunk) {
                if crate::quant::round_half_even(x * inv) != v {
                    clipped += 1;
                }
                let mag = v.unsigned_abs();
                let mut code = mag >> flag;
                if code != all_ones && flag > 0 && (mag >> (flag - 1)) & 1 == 1 {
                    code += 1;
                }
                if code == 0 {
                    zeroed += 1;
                }
            }
            crate::obs::health::note_clips(clipped);
            crate::obs::health::note_razor_group(flag as u8, chunk.len(), zeroed, 0);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::qmax;
    use crate::tensor::Tensor;
    use crate::util::quickcheck::{check, Config, Gen, IntRange, PairGen, VecGen};
    use crate::util::rng::Rng;

    fn spec16_4(g: usize) -> SdrSpec {
        SdrSpec::new(16, 4, g)
    }

    fn spec8_4(g: usize) -> SdrSpec {
        SdrSpec::new(8, 4, g)
    }

    #[test]
    fn effective_bits_match_paper() {
        assert!((spec16_4(8).effective_bits() - 4.5).abs() < 1e-12);
        assert!((spec16_4(16).effective_bits() - 4.25).abs() < 1e-12);
        assert!((spec16_4(32).effective_bits() - 4.125).abs() < 1e-12);
        assert!((spec16_4(64).effective_bits() - 4.0625).abs() < 1e-12);
        assert!((spec16_4(128).effective_bits() - 4.03125).abs() < 1e-12);
    }

    #[test]
    fn single_value_group_examples() {
        let spec = spec16_4(4);
        // 0b1011_0110 = 182: leading one at bit 7, salient bits = top 3
        // (101), flag = 5, truncated MSB of LSBs (bit 4) = 1 -> round up.
        let mut out = [SdrCode::default(); 1];
        let flag = compress_group(&spec, &[182], &mut out);
        assert_eq!(flag, 5);
        assert_eq!(out[0].code, 0b101 + 1);
        assert_eq!(out[0].reconstruct(flag), 0b110 << 5); // 192
    }

    #[test]
    fn all_ones_floors_instead_of_overflowing() {
        let spec = spec16_4(1);
        // 0b1111_1xxx: salient = 111 (all ones) -> must floor, not carry.
        let mut out = [SdrCode::default(); 1];
        let flag = compress_group(&spec, &[0b11111100], &mut out);
        assert_eq!(flag, 5);
        assert_eq!(out[0].code, 0b111, "all-ones must floor");
        assert_eq!(out[0].reconstruct(flag), 0b111 << 5);
    }

    #[test]
    fn zero_group() {
        let spec = spec16_4(4);
        let mut out = [SdrCode::default(); 4];
        let flag = compress_group(&spec, &[0, 0, 0, 0], &mut out);
        assert_eq!(flag, 0);
        assert!(out.iter().all(|c| c.code == 0));
    }

    #[test]
    fn small_values_have_zero_flag_and_are_exact() {
        // All magnitudes fit in the salient width -> lossless.
        let spec = spec16_4(4);
        let vals = [3, -7, 0, 5];
        let mut out = [SdrCode::default(); 4];
        let flag = compress_group(&spec, &vals, &mut out);
        assert_eq!(flag, 0);
        for (c, &v) in out.iter().zip(&vals) {
            assert_eq!(c.reconstruct(flag), v);
        }
    }

    #[test]
    fn outlier_dominates_group_flag() {
        // One outlier forces a large flag; small values get razored to 0.
        let spec = spec16_4(4);
        let vals = [32000, 3, -2, 1];
        let mut out = [SdrCode::default(); 4];
        let flag = compress_group(&spec, &vals, &mut out);
        assert_eq!(flag, 12); // leading one of 32000 is bit 14; 14-2=12
        assert_eq!(out[1].code, 0);
        assert_eq!(out[2].code, 0);
        // outlier survives at 3-bit precision (all-ones code floors, so
        // the bound is 2^flag − 1 rather than the round-to-nearest half)
        let err = (out[0].reconstruct(flag) - 32000).abs();
        assert!(err <= (1 << flag) - 1, "err={err}");
    }

    #[test]
    fn flag_capped_at_max_flag_for_8bit_base() {
        let spec = spec8_4(2);
        let mut out = [SdrCode::default(); 2];
        let flag = compress_group(&spec, &[127, -127], &mut out);
        assert_eq!(flag as u32, spec.max_flag()); // 7-3 = 4
        assert_eq!(out[0].code, 0b111 + 1 - 1); // 127>>4 = 7 (all ones -> floor)
    }

    #[test]
    fn sdr_vector_multi_group_roundtrip_properties() {
        let spec = spec16_4(16);
        let mut rng = Rng::new(42);
        let vals: Vec<i32> = (0..256)
            .map(|_| rng.range_i64(-32767, 32767) as i32)
            .collect();
        let v = SdrVector::compress(spec, &vals, 1.0);
        assert_eq!(v.flags.len(), 16);
        let rec = v.reconstruct();
        for (i, (&orig, &back)) in vals.iter().zip(&rec).enumerate() {
            let f = v.flag_for(i);
            // ≤ 2^f (floor case ≤ 2^f−1, rtn ≤ 2^(f−1))
            let bound = if f == 0 { 0 } else { 1i32 << f };
            assert!(
                (orig - back).abs() <= bound,
                "i={i} orig={orig} back={back} flag={f}"
            );
            // sign never flips
            assert!(orig.signum() * back.signum() >= 0);
        }
    }

    #[test]
    fn prop_reconstruction_error_bound_and_sign() {
        // For every element: |x − x̂| ≤ 2^flag − 1 when floored (all-ones),
        // else ≤ 2^(flag−1); and the sign is preserved (or value → 0).
        let gen = PairGen(
            VecGen { elem: IntRange { lo: -32767, hi: 32767 }, min_len: 1, max_len: 64 },
            IntRange { lo: 1, hi: 64 },
        );
        check("sdr-bound", Config { cases: 400, ..Default::default() }, &gen, |(xs, g)| {
            let spec = spec16_4(*g as usize);
            let vals: Vec<i32> = xs.iter().map(|&x| x as i32).collect();
            let v = SdrVector::compress(spec, &vals, 1.0);
            let rec = v.reconstruct();
            vals.iter().zip(&rec).enumerate().all(|(i, (&o, &b))| {
                let f = v.flag_for(i) as u32;
                let max_err = if f == 0 { 0 } else { 1i64 << f };
                ((o as i64 - b as i64).abs() <= max_err) && (o.signum() * b.signum() >= 0)
            })
        });
    }

    #[test]
    fn prop_codes_fit_target_bits() {
        let gen = VecGen { elem: IntRange { lo: -32767, hi: 32767 }, min_len: 1, max_len: 40 };
        for target in [4u32, 6, 8] {
            check("sdr-code-width", Config { cases: 128, ..Default::default() }, &gen, |xs| {
                let spec = SdrSpec::new(16, target, 8);
                let vals: Vec<i32> = xs.iter().map(|&x| x as i32).collect();
                let v = SdrVector::compress(spec, &vals, 1.0);
                v.codes.iter().all(|c| (c.code as u32) <= spec.salient_max())
                    && v.flags.iter().all(|&f| (f as u32) <= spec.max_flag())
            });
        }
    }

    #[test]
    fn prop_idempotent() {
        // Compressing an already-reconstructed vector is lossless.
        let gen = VecGen { elem: IntRange { lo: -32767, hi: 32767 }, min_len: 1, max_len: 64 };
        check("sdr-idempotent", Config { cases: 200, ..Default::default() }, &gen, |xs| {
            let spec = spec16_4(16);
            let vals: Vec<i32> = xs.iter().map(|&x| x as i32).collect();
            let once = SdrVector::compress(spec, &vals, 1.0).reconstruct();
            let twice = SdrVector::compress(spec, &once, 1.0).reconstruct();
            once == twice
        });
    }

    #[test]
    fn matrix_compress_groups_along_columns() {
        let spec = spec16_4(2);
        let q = QuantTensor {
            shape: vec![2, 4],
            values: vec![100, 2, 3000, 1, /* row1 */ 7, -7, 0, 20000],
            scales: vec![1.0],
            bits: 16,
            granularity: Granularity::PerTensor,
        };
        let m = SdrMatrix::compress(spec, &q);
        assert_eq!(m.groups_per_row(), 2);
        assert_eq!(m.flags.len(), 4);
        // row0 group0 covers {100,2}: leading one bit6 -> flag 4
        assert_eq!(m.row_flags(0)[0], 4);
        // row1 group0 covers {7,-7}: flag 0 (fits salient width)
        assert_eq!(m.row_flags(1)[0], 0);
        let rec = m.reconstruct();
        assert_eq!(rec.values[4], 7);
        assert_eq!(rec.values[5], -7);
    }

    #[test]
    fn fake_quant_is_integer_lattice_of_integer_path() {
        // The float fake-quant output must equal reconstruct()*scale exactly.
        let mut rng = Rng::new(9);
        let mut x = Tensor::zeros(&[8, 64]);
        for v in x.data_mut().iter_mut() {
            *v = rng.heavy_tailed(1.0, 0.02, 25.0);
        }
        let spec = spec16_4(16);
        let fq = qrazor_fake_quant(&x, spec, Granularity::PerTensor);
        let q = QuantTensor::quantize(&x, 16, Granularity::PerTensor);
        let m = SdrMatrix::compress(spec, &q);
        let rec = m.reconstruct();
        for (a, (&v, s)) in fq
            .data()
            .iter()
            .zip(rec.values.iter().zip(std::iter::repeat(q.scales[0])))
        {
            assert_eq!(*a, v as f32 * s);
        }
    }

    #[test]
    fn larger_groups_cannot_reduce_error() {
        // Aggregate squared error is monotone (statistically) in group
        // size: check on heavy-tailed data with a safety margin.
        let mut rng = Rng::new(17);
        let mut x = Tensor::zeros(&[16, 128]);
        for v in x.data_mut().iter_mut() {
            *v = rng.heavy_tailed(1.0, 0.01, 30.0);
        }
        let mut errs = Vec::new();
        for g in [8usize, 32, 128] {
            let fq = qrazor_fake_quant(&x, spec16_4(g), Granularity::PerTensor);
            errs.push(x.mse(&fq));
        }
        assert!(errs[0] <= errs[1] * 1.05, "g8={} g32={}", errs[0], errs[1]);
        assert!(errs[1] <= errs[2] * 1.05, "g32={} g128={}", errs[1], errs[2]);
    }

    #[test]
    fn w4a8_spec_has_more_salient_bits() {
        let s = SdrSpec::new(16, 8, 16);
        assert_eq!(s.salient_bits(), 7);
        assert_eq!(s.salient_max(), 127);
        // More salient bits -> lower error on the same data.
        let mut rng = Rng::new(23);
        let mut x = Tensor::zeros(&[4, 64]);
        for v in x.data_mut().iter_mut() {
            *v = rng.heavy_tailed(1.0, 0.02, 20.0);
        }
        let e4 = x.mse(&qrazor_fake_quant(&x, spec16_4(16), Granularity::PerTensor));
        let e8 = x.mse(&qrazor_fake_quant(&x, s, Granularity::PerTensor));
        assert!(e8 < e4, "e8={e8} e4={e4}");
    }

    #[test]
    fn static_fake_quant_uses_given_scale() {
        let x = Tensor::from_vec(&[1, 2], vec![0.5, -0.25]);
        let spec = spec16_4(2);
        let s = 1.0 / qmax(16) as f32; // amax would be 0.5; force 1.0
        let fq = qrazor_fake_quant_static(&x, spec, s);
        // values quantize to 16384, -8192; group flag = 14-2=12
        // 16384>>12=4 exact; 8192>>12=2 exact
        assert_eq!(fq.data()[0], (4 << 12) as f32 * s);
        assert_eq!(fq.data()[1], -((2 << 12) as f32 * s));
    }

    #[test]
    fn zeroed_fraction_counts_razored_elements() {
        let spec = spec16_4(4);
        let q = QuantTensor {
            shape: vec![1, 4],
            values: vec![32000, 1, 1, 1], // small ones get razored to 0
            scales: vec![1.0],
            bits: 16,
            granularity: Granularity::PerTensor,
        };
        let m = SdrMatrix::compress(spec, &q);
        assert!((m.zeroed_fraction() - 0.75).abs() < 1e-12);
    }

    #[test]
    fn prop_fused_kernel_equals_staged_pipeline() {
        // The §Perf fast path must be bit-identical to the reference
        // staged pipeline for every shape/group/scale.
        let gen = PairGen(
            VecGen {
                elem: crate::util::quickcheck::ActivationLike::default(),
                min_len: 1,
                max_len: 200,
            },
            IntRange { lo: 1, hi: 128 },
        );
        check("fused≡staged", Config { cases: 200, ..Default::default() }, &gen, |(xs, g)| {
            let spec = SdrSpec::new(16, 4, *g as usize);
            let t = Tensor::from_vec(&[xs.len()], xs.clone());
            let scale = crate::quant::absmax_scale(t.data(), 16).max(1e-6);
            // staged reference
            let q = QuantTensor::quantize_static(&t, 16, &[scale]);
            let flat = QuantTensor { shape: vec![1, xs.len()], ..q };
            let staged = SdrMatrix::compress(spec, &flat).dequantize();
            // fused
            let mut fused = vec![0f32; xs.len()];
            qrazor_fake_quant_slice(t.data(), spec, scale, &mut fused);
            staged.data() == fused.as_slice()
        });
    }

    #[test]
    fn gen_smoke() {
        let mut rng = Rng::new(1);
        let g = IntRange { lo: 0, hi: 3 };
        let _ = g.generate(&mut rng);
    }

    #[test]
    fn out_of_base_range_input_saturates_instead_of_aliasing() {
        // 2^20 is far beyond the 16-bit base precision the spec
        // declares. flag caps at max_flag (12), so the raw shifted code
        // would be 256 — way past the 3-bit salient width. The coder
        // must saturate to the all-ones code so the nibble packer (hard
        // range assert) still accepts the group.
        let spec = spec16_4(2);
        let mut out = [SdrCode::default(); 2];
        let flag = compress_group(&spec, &[1 << 20, -3], &mut out);
        assert_eq!(flag as u32, spec.max_flag());
        assert_eq!(out[0].code, spec.salient_max() as u8);
        assert!(!out[0].neg && out[1].neg);
        // and the packed store accepts it without aliasing
        let packed = crate::sdr::packed::pack_nibbles(&out);
        let back = crate::sdr::packed::unpack_nibbles(&packed, 2);
        assert_eq!(back.to_vec(), out.to_vec());
    }
}
