//! Stage 2 of QRazor: **Significant Data Razoring** (paper §4.2–4.3).
//!
//! The base-precision integers from `crate::quant` are compressed per
//! group of `g` elements: the group's *razoring point* — the bit position
//! of the leading one of the bitwise OR of all magnitudes — anchors a
//! salient window of `target_bits − 1` magnitude bits; everything above
//! is provably zero and everything below is rounded away (round to
//! nearest, flooring when the salient bits are all ones so the carry can
//! never overflow into the sign — Algorithm 1's exception). A 4-bit
//! per-group *flag* records how many LSBs were truncated, which is all
//! that's needed to (a) reconstruct values by a left shift, or (b) skip
//! reconstruction entirely and feed a narrow multiplier plus one barrel
//! shift per group pair — the decompression-free GEMM in [`gemm`].
//!
//! Module layout:
//! * [`signmag`] — sign-magnitude view of two's-complement integers and
//!   leading-one arithmetic.
//! * [`razor`] — the SDR coder itself ([`razor::SdrSpec`], [`razor::SdrVector`],
//!   [`razor::SdrMatrix`]).
//! * [`packed`] — nibble-packed storage + flag store with exact memory
//!   accounting (the effective-bits claims of Tables 2/4).
//! * [`store`] — the byte backing of those planes: owned heap buffers
//!   for in-process quantization, or zero-copy windows into a shared
//!   memory-mapped checkpoint (`crate::artifact`).
//! * [`gemm`] — decompression-free integer GEMM (Fig. 3(b)) and the
//!   decompress-then-multiply reference (Fig. 3(a)) it is bit-equal to.

pub mod gemm;
pub mod packed;
pub mod razor;
pub mod signmag;
pub mod store;

pub use razor::{SdrMatrix, SdrSpec, SdrVector};
pub use store::PlaneStore;
