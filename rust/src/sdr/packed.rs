//! Nibble-packed physical storage for SDR data (paper §4.2's memory
//! claim, Tables 2/4's "Eff. Bits" column).
//!
//! A 4-bit code is stored as `sign | 3-bit magnitude` in one nibble, two
//! per byte; group flags are 4-bit, also two per byte. [`PackedSdrMatrix`]
//! is the at-rest representation used by the KV-cache pool and the
//! weight store; it converts losslessly to/from the working
//! [`SdrMatrix`] form and reports its exact memory footprint so the
//! effective-bits arithmetic is *measured*, not asserted.

use super::razor::{SdrCode, SdrMatrix, SdrSpec};
use super::signmag::SignMag;

/// Pack a slice of codes into nibbles (low nibble first).
pub fn pack_nibbles(codes: &[SdrCode]) -> Vec<u8> {
    let mut out = vec![0u8; codes.len().div_ceil(2)];
    for (i, c) in codes.iter().enumerate() {
        debug_assert!(c.code < 8, "code {} exceeds 3 bits", c.code);
        let nib = (SignMag { neg: c.neg, mag: c.code as u32 }).encode(4) as u8;
        if i % 2 == 0 {
            out[i / 2] |= nib;
        } else {
            out[i / 2] |= nib << 4;
        }
    }
    out
}

/// Unpack `n` codes from nibble storage.
pub fn unpack_nibbles(bytes: &[u8], n: usize) -> Vec<SdrCode> {
    assert!(bytes.len() >= n.div_ceil(2));
    (0..n)
        .map(|i| {
            let nib = if i % 2 == 0 {
                bytes[i / 2] & 0x0F
            } else {
                bytes[i / 2] >> 4
            };
            let sm = SignMag::decode(nib as u32, 4);
            SdrCode { neg: sm.neg, code: sm.mag as u8 }
        })
        .collect()
}

/// Pack 4-bit flags two per byte.
pub fn pack_flags(flags: &[u8]) -> Vec<u8> {
    let mut out = vec![0u8; flags.len().div_ceil(2)];
    for (i, &f) in flags.iter().enumerate() {
        debug_assert!(f < 16, "flag {f} exceeds 4 bits");
        if i % 2 == 0 {
            out[i / 2] |= f;
        } else {
            out[i / 2] |= f << 4;
        }
    }
    out
}

pub fn unpack_flags(bytes: &[u8], n: usize) -> Vec<u8> {
    (0..n)
        .map(|i| if i % 2 == 0 { bytes[i / 2] & 0x0F } else { bytes[i / 2] >> 4 })
        .collect()
}

/// At-rest packed SDR matrix. Only valid for `target_bits == 4`
/// (the W4/A4/KV4 formats); 8-bit-target SDR (the A8 ablation) stores
/// codes as plain bytes via [`PackedSdrMatrix::bytes_per_value`] logic.
#[derive(Clone, Debug)]
pub struct PackedSdrMatrix {
    pub spec: SdrSpec,
    pub rows: usize,
    pub cols: usize,
    pub nibbles: Vec<u8>,
    pub flag_bytes: Vec<u8>,
    pub scales: Vec<f32>,
}

impl PackedSdrMatrix {
    pub fn from_matrix(m: &SdrMatrix) -> PackedSdrMatrix {
        assert_eq!(m.spec.target_bits, 4, "nibble packing is a 4-bit format");
        PackedSdrMatrix {
            spec: m.spec,
            rows: m.rows,
            cols: m.cols,
            nibbles: pack_nibbles(&m.codes),
            flag_bytes: pack_flags(&m.flags),
            scales: m.scales.clone(),
        }
    }

    pub fn to_matrix(&self) -> SdrMatrix {
        SdrMatrix {
            spec: self.spec,
            rows: self.rows,
            cols: self.cols,
            codes: unpack_nibbles(&self.nibbles, self.rows * self.cols),
            flags: unpack_flags(&self.flag_bytes, self.rows * self.cols.div_ceil(self.spec.group)),
            scales: self.scales.clone(),
        }
    }

    /// Total payload bytes (codes + flags), excluding scales.
    pub fn payload_bytes(&self) -> usize {
        self.nibbles.len() + self.flag_bytes.len()
    }

    /// Measured effective bits per value.
    pub fn measured_effective_bits(&self) -> f64 {
        self.payload_bytes() as f64 * 8.0 / (self.rows * self.cols) as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::{Granularity, QuantTensor};
    use crate::tensor::Tensor;
    use crate::util::rng::Rng;

    fn random_matrix(rows: usize, cols: usize, g: usize, seed: u64) -> SdrMatrix {
        let mut rng = Rng::new(seed);
        let mut x = Tensor::zeros(&[rows, cols]);
        for v in x.data_mut().iter_mut() {
            *v = rng.heavy_tailed(1.0, 0.02, 30.0);
        }
        let q = QuantTensor::quantize(&x, 16, Granularity::PerTensor);
        SdrMatrix::compress(SdrSpec::new(16, 4, g), &q)
    }

    #[test]
    fn nibble_roundtrip_all_codes() {
        let mut codes = Vec::new();
        for neg in [false, true] {
            for c in 0u8..8 {
                codes.push(SdrCode { neg, code: c });
            }
        }
        codes.push(SdrCode { neg: true, code: 3 }); // odd length
        let packed = pack_nibbles(&codes);
        assert_eq!(packed.len(), 9);
        assert_eq!(unpack_nibbles(&packed, codes.len()), codes);
    }

    #[test]
    fn flags_roundtrip() {
        let flags = vec![0u8, 15, 7, 12, 1];
        let packed = pack_flags(&flags);
        assert_eq!(packed.len(), 3);
        assert_eq!(unpack_flags(&packed, 5), flags);
    }

    #[test]
    fn matrix_pack_roundtrip_lossless() {
        let m = random_matrix(16, 128, 16, 7);
        let p = PackedSdrMatrix::from_matrix(&m);
        let back = p.to_matrix();
        assert_eq!(back.codes, m.codes);
        assert_eq!(back.flags, m.flags);
        assert_eq!(back.reconstruct().values, m.reconstruct().values);
    }

    #[test]
    fn measured_effective_bits_match_spec() {
        for g in [16usize, 32, 128] {
            let m = random_matrix(8, 256, g, 11);
            let p = PackedSdrMatrix::from_matrix(&m);
            let spec_bits = m.spec.effective_bits();
            let measured = p.measured_effective_bits();
            // Padding from odd counts can add a tiny amount; never less.
            assert!(measured >= spec_bits - 1e-9, "g={g}: {measured} < {spec_bits}");
            assert!(measured <= spec_bits + 0.2, "g={g}: {measured} vs {spec_bits}");
        }
    }

    #[test]
    fn packed_is_4x_smaller_than_fp16() {
        let m = random_matrix(32, 256, 32, 13);
        let p = PackedSdrMatrix::from_matrix(&m);
        let fp16_bytes = 32 * 256 * 2;
        let ratio = fp16_bytes as f64 / p.payload_bytes() as f64;
        assert!(ratio > 3.7, "compression ratio {ratio}");
    }

    #[test]
    #[should_panic(expected = "4-bit format")]
    fn rejects_8bit_target() {
        let mut m = random_matrix(2, 16, 8, 1);
        m.spec = SdrSpec::new(16, 8, 8);
        PackedSdrMatrix::from_matrix(&m);
    }
}
