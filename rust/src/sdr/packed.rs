//! Nibble-packed physical storage for SDR data (paper §4.2's memory
//! claim, Tables 2/4's "Eff. Bits" column).
//!
//! A 4-bit code is stored as `sign | 3-bit magnitude` in one nibble, two
//! per byte; group flags are 4-bit, also two per byte. [`PackedSdrMatrix`]
//! is the at-rest representation used by the KV-cache pool and the
//! weight store; it converts losslessly to/from the working
//! [`SdrMatrix`] form and reports its exact memory footprint so the
//! effective-bits arithmetic is *measured*, not asserted.

use super::razor::{SdrCode, SdrMatrix, SdrSpec};
use super::signmag::SignMag;
use super::store::PlaneStore;

/// Signed value of a packed `sign | 3-bit magnitude` nibble, indexed by
/// the raw 4-bit field — the lookup the packed GEMM/attention kernels
/// use to consume nibbles without materializing [`SdrCode`] structs.
/// Index 8 is "negative zero", which decodes to 0 like the hardware.
pub const NIBBLE_SIGNED: [i16; 16] =
    [0, 1, 2, 3, 4, 5, 6, 7, 0, -1, -2, -3, -4, -5, -6, -7];

/// Both codes of a packed byte decoded at once (`[low, high]`),
/// indexed by the raw byte — the 256-entry LUT the packed GEMM uses
/// to decode two codes per table load instead of a shift+mask round
/// per nibble. Bit-identical to [`NIBBLE_SIGNED`] by construction
/// (and by test).
pub const NIBBLE_PAIR_SIGNED: [[i16; 2]; 256] = {
    let mut t = [[0i16; 2]; 256];
    let mut b = 0usize;
    while b < 256 {
        t[b] = [NIBBLE_SIGNED[b & 0x0F], NIBBLE_SIGNED[b >> 4]];
        b += 1;
    }
    t
};

/// Decode `n` consecutive codes starting at nibble index `start` into
/// `out[..n]` — the hot decode of the packed GEMM/attention kernels.
///
/// The main loop is a u64 swizzle: eight packed bytes are read as one
/// little-endian word (**16 codes per load**) and each register byte
/// is decoded through the 256-entry [`NIBBLE_PAIR_SIGNED`] table, so
/// the byte stream is touched once per 16 codes instead of once per 2
/// and the fixed-count inner loop unrolls flat. Unaligned starts (odd
/// nibble index) and ragged tails fall back to the per-byte walk —
/// both paths are bit-identical to [`NIBBLE_SIGNED`] by construction
/// (and by test against [`decode_nibbles_scalar`]).
#[inline]
pub fn decode_nibbles_into(bytes: &[u8], start: usize, n: usize, out: &mut [i16]) {
    debug_assert!(out.len() >= n);
    if n == 0 {
        return;
    }
    let mut i = 0usize; // codes written
    let mut pos = start; // absolute nibble index
    if pos % 2 == 1 {
        out[0] = NIBBLE_PAIR_SIGNED[bytes[pos / 2] as usize][1];
        i = 1;
        pos += 1;
    }
    // u64 swizzle: 8 whole bytes → 16 codes per load. `pos` is even
    // here, and codes `pos..pos + 16` live in bytes `pos/2..pos/2 + 8`
    // — within the store whenever the caller's window is.
    while i + 16 <= n {
        let b = pos / 2;
        let word = u64::from_le_bytes(bytes[b..b + 8].try_into().unwrap());
        for s in 0..8 {
            let pair = NIBBLE_PAIR_SIGNED[((word >> (8 * s)) & 0xFF) as usize];
            out[i + 2 * s] = pair[0];
            out[i + 2 * s + 1] = pair[1];
        }
        i += 16;
        pos += 16;
    }
    while i + 1 < n {
        let pair = NIBBLE_PAIR_SIGNED[bytes[pos / 2] as usize];
        out[i] = pair[0];
        out[i + 1] = pair[1];
        i += 2;
        pos += 2;
    }
    if i < n {
        out[i] = NIBBLE_PAIR_SIGNED[bytes[pos / 2] as usize][0];
    }
}

/// The previous SIMD rung — one pair-LUT hit per *byte load*, no u64
/// swizzle. Kept as the bit-identity reference for
/// [`decode_nibbles_into`] and as the `perf_hotpaths` baseline that
/// reports the swizzle's measured delta.
#[inline]
pub fn decode_nibbles_scalar(bytes: &[u8], start: usize, n: usize, out: &mut [i16]) {
    debug_assert!(out.len() >= n);
    if n == 0 {
        return;
    }
    let mut i = 0usize;
    let mut pos = start;
    if pos % 2 == 1 {
        out[0] = NIBBLE_PAIR_SIGNED[bytes[pos / 2] as usize][1];
        i = 1;
        pos += 1;
    }
    while i + 1 < n {
        let pair = NIBBLE_PAIR_SIGNED[bytes[pos / 2] as usize];
        out[i] = pair[0];
        out[i + 1] = pair[1];
        i += 2;
        pos += 2;
    }
    if i < n {
        out[i] = NIBBLE_PAIR_SIGNED[bytes[pos / 2] as usize][0];
    }
}

/// Signed value of a byte-coded `sign | 7-bit magnitude` code, indexed
/// by the raw byte — the A8 analog of [`NIBBLE_SIGNED`], consumed by
/// the W4A8 packed GEMM. Index 128 is "negative zero", which decodes
/// to 0 like the hardware.
pub const BYTE_SIGNED: [i16; 256] = {
    let mut t = [0i16; 256];
    let mut b = 0usize;
    while b < 256 {
        let mag = (b & 0x7F) as i16;
        t[b] = if b & 0x80 != 0 { -mag } else { mag };
        b += 1;
    }
    t
};

/// Nibble `i` of a packed byte stream (low nibble first).
#[inline(always)]
pub fn nibble_at(bytes: &[u8], i: usize) -> u8 {
    if i % 2 == 0 {
        bytes[i / 2] & 0x0F
    } else {
        bytes[i / 2] >> 4
    }
}

/// Pack a slice of codes into nibbles (low nibble first).
///
/// Hard-asserts the 3-bit range even in release builds: an oversized
/// code would otherwise alias into its neighbor's nibble and corrupt
/// the store silently.
pub fn pack_nibbles(codes: &[SdrCode]) -> Vec<u8> {
    let mut out = vec![0u8; codes.len().div_ceil(2)];
    for (i, c) in codes.iter().enumerate() {
        assert!(c.code < 8, "code {} exceeds 3 bits", c.code);
        let nib = (SignMag { neg: c.neg, mag: c.code as u32 }).encode(4) as u8;
        if i % 2 == 0 {
            out[i / 2] |= nib;
        } else {
            out[i / 2] |= nib << 4;
        }
    }
    out
}

/// Unpack `n` codes from nibble storage.
pub fn unpack_nibbles(bytes: &[u8], n: usize) -> Vec<SdrCode> {
    assert!(bytes.len() >= n.div_ceil(2), "code store holds < {n} codes");
    (0..n)
        .map(|i| {
            let sm = SignMag::decode(nibble_at(bytes, i) as u32, 4);
            SdrCode { neg: sm.neg, code: sm.mag as u8 }
        })
        .collect()
}

/// Pack 4-bit flags two per byte. Hard-asserts the 4-bit range even in
/// release builds — see [`pack_nibbles`].
pub fn pack_flags(flags: &[u8]) -> Vec<u8> {
    let mut out = vec![0u8; flags.len().div_ceil(2)];
    for (i, &f) in flags.iter().enumerate() {
        assert!(f < 16, "flag {f} exceeds 4 bits");
        if i % 2 == 0 {
            out[i / 2] |= f;
        } else {
            out[i / 2] |= f << 4;
        }
    }
    out
}

/// Unpack `n` flags from nibble storage.
pub fn unpack_flags(bytes: &[u8], n: usize) -> Vec<u8> {
    assert!(bytes.len() >= n.div_ceil(2), "flag store holds < {n} flags");
    (0..n).map(|i| nibble_at(bytes, i)).collect()
}

/// At-rest packed SDR matrix. Only valid for `target_bits == 4`
/// (the W4/A4/KV4 formats); 8-bit-target SDR (the A8 ablation) stores
/// codes as plain bytes via [`PackedSdrMatrix::bytes_per_value`] logic.
///
/// The nibble and flag planes live in a [`PlaneStore`]: owned bytes
/// when quantized in-process, zero-copy windows into a shared mapped
/// checkpoint when loaded through `crate::artifact`. Either way the
/// planes deref to `&[u8]`, so every consumer is backing-agnostic.
#[derive(Clone, Debug)]
pub struct PackedSdrMatrix {
    pub spec: SdrSpec,
    pub rows: usize,
    pub cols: usize,
    pub nibbles: PlaneStore,
    pub flag_bytes: PlaneStore,
    pub scales: Vec<f32>,
}

impl PackedSdrMatrix {
    pub fn from_matrix(m: &SdrMatrix) -> PackedSdrMatrix {
        assert_eq!(m.spec.target_bits, 4, "nibble packing is a 4-bit format");
        PackedSdrMatrix {
            spec: m.spec,
            rows: m.rows,
            cols: m.cols,
            nibbles: pack_nibbles(&m.codes).into(),
            flag_bytes: pack_flags(&m.flags).into(),
            scales: m.scales.clone(),
        }
    }

    pub fn to_matrix(&self) -> SdrMatrix {
        SdrMatrix {
            spec: self.spec,
            rows: self.rows,
            cols: self.cols,
            codes: unpack_nibbles(&self.nibbles, self.rows * self.cols),
            flags: unpack_flags(&self.flag_bytes, self.rows * self.cols.div_ceil(self.spec.group)),
            scales: self.scales.clone(),
        }
    }

    /// Groups along each row (flags per row).
    #[inline]
    pub fn groups_per_row(&self) -> usize {
        self.cols.div_ceil(self.spec.group)
    }

    /// Total payload bytes (codes + flags), excluding scales.
    pub fn payload_bytes(&self) -> usize {
        self.nibbles.len() + self.flag_bytes.len()
    }

    /// Payload bytes the *unpacked* working form ([`SdrMatrix`]) moves
    /// for the same data: one byte per code plus one byte per flag. The
    /// packed-vs-unpacked traffic ratio in the Fig. 3 / serving benches
    /// is `payload_bytes() / unpacked_payload_bytes()` ≈ 4.25/8.5 bits.
    pub fn unpacked_payload_bytes(&self) -> usize {
        self.rows * self.cols + self.rows * self.groups_per_row()
    }

    /// Measured effective bits per value.
    pub fn measured_effective_bits(&self) -> f64 {
        self.payload_bytes() as f64 * 8.0 / (self.rows * self.cols) as f64
    }
}

/// At-rest **byte-coded** SDR matrix for the 8-bit-target formats — the
/// A8 operand of W4A8. One `sign | 7-bit magnitude` byte per code plus
/// nibble-packed group flags: the same flag store as
/// [`PackedSdrMatrix`], twice the code bytes (8.5 vs 4.25 effective
/// bits), consumed directly by
/// [`crate::sdr::gemm::gemm_razored_packed_a8`] so W4A8 skips the
/// staged fake-quant path just like W4A4 does.
#[derive(Clone, Debug)]
pub struct ByteSdrMatrix {
    pub spec: SdrSpec,
    pub rows: usize,
    pub cols: usize,
    /// Sign-magnitude code bytes, row-major, one per element.
    pub codes: PlaneStore,
    pub flag_bytes: PlaneStore,
    pub scales: Vec<f32>,
}

impl ByteSdrMatrix {
    pub fn from_matrix(m: &SdrMatrix) -> ByteSdrMatrix {
        assert_eq!(m.spec.target_bits, 8, "byte coding is an 8-bit format");
        let codes: Vec<u8> = m
            .codes
            .iter()
            .map(|c| {
                assert!(c.code < 128, "code {} exceeds 7 bits", c.code);
                ((c.neg as u8) << 7) | c.code
            })
            .collect();
        ByteSdrMatrix {
            spec: m.spec,
            rows: m.rows,
            cols: m.cols,
            codes: codes.into(),
            flag_bytes: pack_flags(&m.flags).into(),
            scales: m.scales.clone(),
        }
    }

    pub fn to_matrix(&self) -> SdrMatrix {
        SdrMatrix {
            spec: self.spec,
            rows: self.rows,
            cols: self.cols,
            codes: self
                .codes
                .iter()
                .map(|&b| SdrCode { neg: b & 0x80 != 0, code: b & 0x7F })
                .collect(),
            flags: unpack_flags(&self.flag_bytes, self.rows * self.cols.div_ceil(self.spec.group)),
            scales: self.scales.clone(),
        }
    }

    /// Groups along each row (flags per row).
    #[inline]
    pub fn groups_per_row(&self) -> usize {
        self.cols.div_ceil(self.spec.group)
    }

    /// Total payload bytes (codes + flags), excluding scales.
    pub fn payload_bytes(&self) -> usize {
        self.codes.len() + self.flag_bytes.len()
    }

    /// Measured effective bits per value (≈ 8.5 at g16).
    pub fn measured_effective_bits(&self) -> f64 {
        self.payload_bytes() as f64 * 8.0 / (self.rows * self.cols) as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::{Granularity, QuantTensor};
    use crate::tensor::Tensor;
    use crate::util::rng::Rng;

    fn random_matrix(rows: usize, cols: usize, g: usize, seed: u64) -> SdrMatrix {
        let mut rng = Rng::new(seed);
        let mut x = Tensor::zeros(&[rows, cols]);
        for v in x.data_mut().iter_mut() {
            *v = rng.heavy_tailed(1.0, 0.02, 30.0);
        }
        let q = QuantTensor::quantize(&x, 16, Granularity::PerTensor);
        SdrMatrix::compress(SdrSpec::new(16, 4, g), &q)
    }

    #[test]
    fn nibble_roundtrip_all_codes() {
        let mut codes = Vec::new();
        for neg in [false, true] {
            for c in 0u8..8 {
                codes.push(SdrCode { neg, code: c });
            }
        }
        codes.push(SdrCode { neg: true, code: 3 }); // odd length
        let packed = pack_nibbles(&codes);
        assert_eq!(packed.len(), 9);
        assert_eq!(unpack_nibbles(&packed, codes.len()), codes);
    }

    #[test]
    fn flags_roundtrip() {
        let flags = vec![0u8, 15, 7, 12, 1];
        let packed = pack_flags(&flags);
        assert_eq!(packed.len(), 3);
        assert_eq!(unpack_flags(&packed, 5), flags);
    }

    #[test]
    fn matrix_pack_roundtrip_lossless() {
        let m = random_matrix(16, 128, 16, 7);
        let p = PackedSdrMatrix::from_matrix(&m);
        let back = p.to_matrix();
        assert_eq!(back.codes, m.codes);
        assert_eq!(back.flags, m.flags);
        assert_eq!(back.reconstruct().values, m.reconstruct().values);
    }

    #[test]
    fn measured_effective_bits_match_spec() {
        for g in [16usize, 32, 128] {
            let m = random_matrix(8, 256, g, 11);
            let p = PackedSdrMatrix::from_matrix(&m);
            let spec_bits = m.spec.effective_bits();
            let measured = p.measured_effective_bits();
            // Padding from odd counts can add a tiny amount; never less.
            assert!(measured >= spec_bits - 1e-9, "g={g}: {measured} < {spec_bits}");
            assert!(measured <= spec_bits + 0.2, "g={g}: {measured} vs {spec_bits}");
        }
    }

    #[test]
    fn packed_is_4x_smaller_than_fp16() {
        let m = random_matrix(32, 256, 32, 13);
        let p = PackedSdrMatrix::from_matrix(&m);
        let fp16_bytes = 32 * 256 * 2;
        let ratio = fp16_bytes as f64 / p.payload_bytes() as f64;
        assert!(ratio > 3.7, "compression ratio {ratio}");
    }

    #[test]
    #[should_panic(expected = "4-bit format")]
    fn rejects_8bit_target() {
        let mut m = random_matrix(2, 16, 8, 1);
        m.spec = SdrSpec::new(16, 8, 8);
        PackedSdrMatrix::from_matrix(&m);
    }

    fn random_a8_matrix(rows: usize, cols: usize, g: usize, seed: u64) -> SdrMatrix {
        let mut rng = Rng::new(seed);
        let mut x = Tensor::zeros(&[rows, cols]);
        for v in x.data_mut().iter_mut() {
            *v = rng.heavy_tailed(1.0, 0.02, 30.0);
        }
        let q = QuantTensor::quantize(&x, 16, Granularity::PerTensor);
        SdrMatrix::compress(SdrSpec::new(16, 8, g), &q)
    }

    #[test]
    fn byte_signed_lut_decodes_sign_magnitude() {
        for b in 0u16..256 {
            let mag = (b & 0x7F) as i16;
            let want = if b & 0x80 != 0 { -mag } else { mag };
            assert_eq!(BYTE_SIGNED[b as usize], want, "byte {b}");
        }
        assert_eq!(BYTE_SIGNED[128], 0, "negative zero decodes to 0");
    }

    #[test]
    fn byte_matrix_roundtrip_lossless() {
        for (rows, cols, g) in [(4usize, 64usize, 16usize), (3, 37, 8), (1, 1, 4)] {
            let m = random_a8_matrix(rows, cols, g, (rows * 100 + cols) as u64);
            let b = ByteSdrMatrix::from_matrix(&m);
            let back = b.to_matrix();
            assert_eq!(back.codes, m.codes, "{rows}x{cols} g{g}");
            assert_eq!(back.flags, m.flags, "{rows}x{cols} g{g}");
            assert_eq!(back.reconstruct().values, m.reconstruct().values);
            // every code byte decodes through the LUT to the code's sign
            for (byte, c) in b.codes.iter().zip(&m.codes) {
                assert_eq!(BYTE_SIGNED[*byte as usize] as i32, c.signed());
            }
        }
    }

    #[test]
    fn byte_matrix_effective_bits_about_8_5() {
        let m = random_a8_matrix(8, 256, 16, 11);
        let b = ByteSdrMatrix::from_matrix(&m);
        let eff = b.measured_effective_bits();
        assert!((8.2..8.6).contains(&eff), "effective bits {eff}");
        // exactly twice the nibble store's code bytes, same flag bytes
        assert_eq!(b.codes.len(), 8 * 256);
    }

    #[test]
    #[should_panic(expected = "8-bit format")]
    fn byte_matrix_rejects_4bit_target() {
        let m = random_matrix(2, 16, 8, 1);
        ByteSdrMatrix::from_matrix(&m);
    }

    #[test]
    fn nibble_signed_lut_matches_signmag_decode() {
        for nib in 0u32..16 {
            let sm = SignMag::decode(nib, 4);
            let signed = if sm.neg { -(sm.mag as i16) } else { sm.mag as i16 };
            assert_eq!(NIBBLE_SIGNED[nib as usize], signed, "nibble {nib}");
        }
    }

    #[test]
    fn nibble_pair_lut_matches_single_nibble_lut() {
        for b in 0u16..256 {
            let pair = NIBBLE_PAIR_SIGNED[b as usize];
            assert_eq!(pair[0], NIBBLE_SIGNED[(b & 0x0F) as usize], "byte {b} low");
            assert_eq!(pair[1], NIBBLE_SIGNED[(b >> 4) as usize], "byte {b} high");
        }
    }

    #[test]
    fn decode_nibbles_into_handles_every_alignment() {
        let m = random_matrix(4, 41, 8, 33); // odd row length
        let p = PackedSdrMatrix::from_matrix(&m);
        let total = 4 * 41;
        let reference: Vec<i16> = (0..total)
            .map(|i| NIBBLE_SIGNED[nibble_at(&p.nibbles, i) as usize])
            .collect();
        // every (start, len) window, aligned and unaligned
        for start in 0..8usize {
            for len in [0usize, 1, 2, 3, 7, 8, 40, total - start] {
                let mut out = vec![99i16; len];
                decode_nibbles_into(&p.nibbles, start, len, &mut out);
                assert_eq!(
                    out,
                    &reference[start..start + len],
                    "start {start} len {len}"
                );
            }
        }
    }

    #[test]
    fn u64_swizzle_matches_scalar_walk_on_every_window() {
        // The SIMD rung's bit-identity contract: the 16-codes-per-load
        // swizzle path and the per-byte walk decode every (start, len)
        // window identically, including windows straddling the
        // head-fixup, the 16-code main loop, and the ragged tail.
        let m = random_matrix(6, 53, 8, 77); // odd row length
        let p = PackedSdrMatrix::from_matrix(&m);
        let total = 6 * 53;
        for start in [0usize, 1, 2, 3, 15, 16, 17, 31] {
            for len in [0usize, 1, 15, 16, 17, 32, 33, 100, total - start] {
                let mut a = vec![7i16; len];
                let mut b = vec![-7i16; len];
                decode_nibbles_into(&p.nibbles, start, len, &mut a);
                decode_nibbles_scalar(&p.nibbles, start, len, &mut b);
                assert_eq!(a, b, "start {start} len {len}");
            }
        }
    }

    #[test]
    fn nibble_at_matches_unpack() {
        let m = random_matrix(3, 37, 8, 21); // odd row length
        let p = PackedSdrMatrix::from_matrix(&m);
        let codes = unpack_nibbles(&p.nibbles, 3 * 37);
        for (i, c) in codes.iter().enumerate() {
            assert_eq!(
                NIBBLE_SIGNED[nibble_at(&p.nibbles, i) as usize] as i32,
                c.signed(),
                "index {i}"
            );
        }
    }

    #[test]
    #[should_panic(expected = "exceeds 3 bits")]
    fn oversized_code_is_rejected_not_aliased() {
        // Before the hard assert, code 9 would smear bits into the
        // neighboring nibble in release builds.
        pack_nibbles(&[SdrCode { neg: false, code: 9 }]);
    }

    #[test]
    #[should_panic(expected = "exceeds 4 bits")]
    fn oversized_flag_is_rejected_not_aliased() {
        pack_flags(&[17u8]);
    }

    #[test]
    #[should_panic(expected = "flag store holds")]
    fn unpack_flags_checks_bounds() {
        unpack_flags(&[0x21u8], 5); // one byte holds at most 2 flags
    }

    #[test]
    fn ragged_roundtrip_odd_cols_and_tail_group() {
        // cols=37 with g=8: ragged tail group of 5, odd total nibble
        // count per row — exercises both padding paths.
        for (rows, cols, g) in [(1usize, 1usize, 4usize), (3, 37, 8), (5, 50, 16), (2, 7, 16)] {
            let m = random_matrix(rows, cols, g, (rows * 100 + cols) as u64);
            let p = PackedSdrMatrix::from_matrix(&m);
            let back = p.to_matrix();
            assert_eq!(back.codes, m.codes, "{rows}x{cols} g{g}");
            assert_eq!(back.flags, m.flags, "{rows}x{cols} g{g}");
            assert_eq!(
                back.reconstruct().values,
                m.reconstruct().values,
                "{rows}x{cols} g{g}"
            );
        }
    }

    #[test]
    fn all_negative_group_roundtrips() {
        let q = QuantTensor {
            shape: vec![2, 8],
            values: vec![-300, -5, -1, -32767, -2, -9, -100, -4000,
                         -1, -1, -1, -1, -1, -1, -1, -1],
            scales: vec![1.0],
            bits: 16,
            granularity: Granularity::PerTensor,
        };
        let m = SdrMatrix::compress(SdrSpec::new(16, 4, 4), &q);
        assert!(m.codes.iter().all(|c| c.neg || c.code == 0));
        let p = PackedSdrMatrix::from_matrix(&m);
        let back = p.to_matrix();
        assert_eq!(back.codes, m.codes);
        assert!(back.reconstruct().values.iter().all(|&v| v <= 0));
    }

    #[test]
    fn unpacked_payload_is_about_twice_packed() {
        let m = random_matrix(8, 128, 16, 3);
        let p = PackedSdrMatrix::from_matrix(&m);
        let ratio = p.payload_bytes() as f64 / p.unpacked_payload_bytes() as f64;
        assert!((0.49..=0.51).contains(&ratio), "ratio {ratio}");
    }
}
