//! Static-scale calibration (paper §5.1).
//!
//! Activations and KV caches are quantized **online but with static
//! scales**: a calibration pass over N sequences (the paper uses 128
//! WikiText-2 samples) records the absolute maximum observed at every
//! quantization site; those maxima become fixed per-tensor scales baked
//! into the serving configuration. This module is the bookkeeping for
//! that pass.

use std::collections::BTreeMap;

use super::absmax::absmax_scale_from_amax;
use crate::util::json::Json;

/// Running calibration state: per-site absolute maxima.
#[derive(Clone, Debug, Default)]
pub struct Calibrator {
    amax: BTreeMap<String, f32>,
    observations: BTreeMap<String, u64>,
}

impl Calibrator {
    pub fn new() -> Self {
        Self::default()
    }

    /// Record one batch of values for a named site (e.g. "layer3.qkv_in").
    pub fn observe(&mut self, site: &str, values: &[f32]) {
        let batch_max = values.iter().fold(0f32, |m, &x| m.max(x.abs()));
        let e = self.amax.entry(site.to_string()).or_insert(0.0);
        *e = e.max(batch_max);
        *self.observations.entry(site.to_string()).or_insert(0) += 1;
    }

    pub fn amax(&self, site: &str) -> Option<f32> {
        self.amax.get(site).copied()
    }

    pub fn sites(&self) -> impl Iterator<Item = &str> {
        self.amax.keys().map(|s| s.as_str())
    }

    /// Scale every recorded amax by `factor`. A bench/test helper:
    /// attenuating the calibration simulates serving with stale scales
    /// against live activations that have drifted `1/factor`× past the
    /// frozen range (the `serve_throughput --health` shift workload).
    pub fn attenuate(&mut self, factor: f32) {
        for a in self.amax.values_mut() {
            *a *= factor;
        }
    }

    /// Freeze into a static scale table for a given activation bit width.
    pub fn freeze(&self, bits: u32) -> StaticScales {
        StaticScales {
            bits,
            scales: self
                .amax
                .iter()
                .map(|(k, &a)| (k.clone(), absmax_scale_from_amax(a, bits)))
                .collect(),
        }
    }
}

/// Frozen per-site scales — the artifact the serving path loads.
#[derive(Clone, Debug, PartialEq)]
pub struct StaticScales {
    pub bits: u32,
    pub scales: BTreeMap<String, f32>,
}

impl StaticScales {
    /// Dequantization scale for a site. A site calibration never saw is
    /// a config bug (calibration/serve site-name skew) — it used to
    /// panic, but a serving stack should degrade, not die: the miss is
    /// counted in the health registry (`qrazor_scale_misses`), the site
    /// name is logged once, and a benign unit-amax fallback scale is
    /// returned so the forward stays finite while the skew is visible.
    pub fn scale(&self, site: &str) -> f32 {
        match self.scales.get(site) {
            Some(&s) => s,
            None => {
                crate::obs::health::note_scale_miss(site);
                absmax_scale_from_amax(1.0, self.bits)
            }
        }
    }

    pub fn get(&self, site: &str) -> Option<f32> {
        self.scales.get(site).copied()
    }

    pub fn to_json(&self) -> Json {
        let mut obj = Json::obj();
        obj.set("bits", Json::from(self.bits));
        let mut scales = Json::obj();
        for (k, &v) in &self.scales {
            scales.set(k, Json::Num(v as f64));
        }
        obj.set("scales", scales);
        obj
    }

    pub fn from_json(j: &Json) -> anyhow::Result<StaticScales> {
        let bits = j.req("bits")?.as_usize().unwrap_or(16) as u32;
        let mut scales = BTreeMap::new();
        if let Json::Obj(m) = j.req("scales")? {
            for (k, v) in m {
                scales.insert(
                    k.clone(),
                    v.as_f64().ok_or_else(|| anyhow::anyhow!("scale '{k}' not a number"))? as f32,
                );
            }
        } else {
            anyhow::bail!("'scales' is not an object");
        }
        Ok(StaticScales { bits, scales })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::absmax::qmax;

    #[test]
    fn observes_running_max() {
        let mut c = Calibrator::new();
        c.observe("x", &[0.5, -1.0]);
        c.observe("x", &[0.25]);
        c.observe("x", &[-3.0, 2.0]);
        assert_eq!(c.amax("x"), Some(3.0));
        assert_eq!(c.amax("y"), None);
    }

    #[test]
    fn freeze_converts_amax_to_scale() {
        let mut c = Calibrator::new();
        c.observe("act", &[2.0, -4.0]);
        let s = c.freeze(16);
        assert!((s.scale("act") - 4.0 / qmax(16) as f32).abs() < 1e-10);
    }

    #[test]
    fn missing_site_counts_and_falls_back() {
        let c = Calibrator::new();
        let s = c.freeze(8);
        let fallback = s.scale("calibrate_test.ghost");
        // benign unit-amax fallback, not zero (zero would silently
        // flatten the whole tensor)
        assert!((fallback - 1.0 / qmax(8) as f32).abs() < 1e-10);
        // the miss is counted (retry tolerates a concurrent
        // health_reset from the obs unit tests sharing this process)
        let counted = (0..3).any(|_| {
            let before = crate::obs::health::scale_miss_count();
            let _ = s.scale("calibrate_test.ghost");
            crate::obs::health::scale_miss_count() > before
        });
        assert!(counted);
    }

    #[test]
    fn attenuate_shrinks_frozen_scales() {
        let mut c = Calibrator::new();
        c.observe("act", &[2.0, -4.0]);
        let full = c.freeze(16).scale("act");
        c.attenuate(0.5);
        let half = c.freeze(16).scale("act");
        assert!((half - full * 0.5).abs() < 1e-12);
    }

    #[test]
    fn json_roundtrip() {
        let mut c = Calibrator::new();
        c.observe("a.in", &[1.5]);
        c.observe("b.kv", &[0.125, -8.0]);
        let s = c.freeze(8);
        let j = s.to_json();
        let back = StaticScales::from_json(&Json::parse(&j.to_string()).unwrap()).unwrap();
        assert_eq!(back, s);
    }

    #[test]
    fn multiple_sites_independent() {
        let mut c = Calibrator::new();
        c.observe("small", &[0.01]);
        c.observe("big", &[100.0]);
        let s = c.freeze(16);
        assert!(s.scale("big") / s.scale("small") > 9_000.0);
    }
}
