//! Absolute-max scaling quantization (paper §3).
//!
//! `X_q = round(Q_max / |X_max| · X)`, `X̂ = |X_max| / Q_max · X_q` with
//! `Q_max = 2^(b−1) − 1`. Round-to-nearest-even (matching both IEEE and
//! jnp.round so the L1/L2 float path lands on the identical lattice),
//! symmetric range, clamped. Values are held as `i32` in two's
//! complement; the sign-magnitude view required by SDR lives in
//! `crate::sdr::signmag`.

use super::Granularity;
use crate::tensor::Tensor;

/// A tensor quantized to `bits`-bit signed integers with absmax scaling.
#[derive(Clone, Debug)]
pub struct QuantTensor {
    pub shape: Vec<usize>,
    /// Quantized values in [-(2^(bits-1)-1), 2^(bits-1)-1].
    pub values: Vec<i32>,
    /// One scale (PerTensor) or `shape[0]` scales (PerChannel); the
    /// *dequantization* multiplier: x̂ = q · scale.
    pub scales: Vec<f32>,
    pub bits: u32,
    pub granularity: Granularity,
}

/// Largest representable magnitude for a bit width (incl. sign bit).
pub fn qmax(bits: u32) -> i32 {
    assert!((2..=31).contains(&bits), "bits={bits}");
    (1 << (bits - 1)) - 1
}

/// Round-to-nearest-even, the rounding used at the quantization stage.
pub fn round_half_even(x: f32) -> i32 {
    // f32::round_ties_even is stable since 1.77
    x.round_ties_even() as i32
}

/// Quantize one slice with a given scale (dequant multiplier).
fn quantize_slice(xs: &[f32], scale: f32, bits: u32, out: &mut Vec<i32>) {
    let q = qmax(bits);
    let inv = if scale > 0.0 { 1.0 / scale } else { 0.0 };
    if crate::obs::health::health_enabled() {
        // Counting variant: clip events (values the symmetric range
        // clamp actually moved) feed the per-(layer, site) health
        // counters. Static scales make clips the canonical "live data
        // outgrew calibration" signal.
        let mut clipped = 0usize;
        for &x in xs {
            let r = round_half_even(x * inv);
            let v = r.clamp(-q, q);
            if r != v {
                clipped += 1;
            }
            out.push(v);
        }
        crate::obs::health::note_clips(clipped);
        return;
    }
    for &x in xs {
        let v = round_half_even(x * inv).clamp(-q, q);
        out.push(v);
    }
}

/// Compute the absmax-derived scale for a slice: |X_max| / Q_max.
/// A zero slice gets scale 0 (all values quantize to 0).
pub fn absmax_scale(xs: &[f32], bits: u32) -> f32 {
    let amax = xs.iter().fold(0f32, |m, &x| m.max(x.abs()));
    absmax_scale_from_amax(amax, bits)
}

/// Scale from a known absolute maximum (calibration path).
pub fn absmax_scale_from_amax(amax: f32, bits: u32) -> f32 {
    if amax == 0.0 {
        0.0
    } else {
        amax / qmax(bits) as f32
    }
}

impl QuantTensor {
    /// Quantize `x` with dynamically computed absmax scales. Used for
    /// weights (offline) and for establishing calibration statistics;
    /// the online activation path uses [`QuantTensor::quantize_static`].
    pub fn quantize(x: &Tensor<f32>, bits: u32, granularity: Granularity) -> QuantTensor {
        match granularity {
            Granularity::PerTensor => {
                let scale = absmax_scale(x.data(), bits);
                Self::quantize_static(x, bits, &[scale])
            }
            Granularity::PerChannel => {
                assert_eq!(x.ndim(), 2, "PerChannel needs a 2-D tensor");
                let scales: Vec<f32> = (0..x.shape()[0])
                    .map(|r| absmax_scale(x.row(r), bits))
                    .collect();
                let mut q = Self::quantize_static(x, bits, &scales);
                q.granularity = Granularity::PerChannel;
                q
            }
        }
    }

    /// Quantize with externally supplied (static/calibrated) scales:
    /// one scale → per-tensor; `shape[0]` scales → per-channel.
    pub fn quantize_static(x: &Tensor<f32>, bits: u32, scales: &[f32]) -> QuantTensor {
        let mut values = Vec::with_capacity(x.len());
        if scales.len() == 1 {
            quantize_slice(x.data(), scales[0], bits, &mut values);
        } else {
            assert_eq!(x.ndim(), 2);
            assert_eq!(scales.len(), x.shape()[0]);
            for r in 0..x.shape()[0] {
                quantize_slice(x.row(r), scales[r], bits, &mut values);
            }
        }
        QuantTensor {
            shape: x.shape().to_vec(),
            values,
            scales: scales.to_vec(),
            bits,
            granularity: if scales.len() == 1 {
                Granularity::PerTensor
            } else {
                Granularity::PerChannel
            },
        }
    }

    /// Scale applying to row `r` (row-major 2-D) or the whole tensor.
    pub fn scale_for_row(&self, r: usize) -> f32 {
        if self.scales.len() == 1 {
            self.scales[0]
        } else {
            self.scales[r]
        }
    }

    /// Dequantize back to f32.
    pub fn dequantize(&self) -> Tensor<f32> {
        let mut out = Vec::with_capacity(self.values.len());
        if self.scales.len() == 1 {
            let s = self.scales[0];
            out.extend(self.values.iter().map(|&v| v as f32 * s));
        } else {
            let cols: usize = self.shape[1..].iter().product();
            for (r, chunk) in self.values.chunks(cols).enumerate() {
                let s = self.scales[r];
                out.extend(chunk.iter().map(|&v| v as f32 * s));
            }
        }
        Tensor::from_vec(&self.shape, out)
    }

    /// Number of rows for per-channel traversal.
    pub fn rows(&self) -> usize {
        if self.shape.len() >= 2 {
            self.shape[0]
        } else {
            1
        }
    }

    /// Elements per row: a 1-D tensor is a single row of its full
    /// length; N-D tensors flatten every trailing dimension. Degenerate
    /// shapes ([], [0], [2, 0]) report their true element counts rather
    /// than being rounded up to 1 like the old `product().max(..)`
    /// expression did.
    pub fn cols(&self) -> usize {
        match self.shape.len() {
            0 => 0,
            1 => self.shape[0],
            _ => self.shape[1..].iter().product(),
        }
    }
}

/// Fake-quantization: quantize then dequantize in one step — the float
/// lattice that the L2/JAX path computes on, used by all accuracy
/// experiments and asserted (exactly) equal to the integer path.
pub fn fake_quant(x: &Tensor<f32>, bits: u32, granularity: Granularity) -> Tensor<f32> {
    QuantTensor::quantize(x, bits, granularity).dequantize()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::quickcheck::{check, ActivationLike, Config, Gen, VecGen};
    use crate::util::rng::Rng;

    #[test]
    fn qmax_values() {
        assert_eq!(qmax(8), 127);
        assert_eq!(qmax(16), 32767);
        assert_eq!(qmax(4), 7);
    }

    #[test]
    fn roundtrip_error_bounded_by_half_step() {
        let x = Tensor::from_vec(&[5], vec![0.1, -0.5, 0.9, 1.0, -1.0]);
        let q = QuantTensor::quantize(&x, 8, Granularity::PerTensor);
        let xh = q.dequantize();
        let step = 1.0 / 127.0; // amax = 1.0
        for (a, b) in x.data().iter().zip(xh.data()) {
            assert!((a - b).abs() <= step / 2.0 + 1e-7, "{a} vs {b}");
        }
    }

    #[test]
    fn absmax_is_representable_exactly() {
        // The element with |x| = amax maps to ±qmax exactly.
        let x = Tensor::from_vec(&[3], vec![0.3, -2.5, 1.1]);
        let q = QuantTensor::quantize(&x, 8, Granularity::PerTensor);
        assert_eq!(q.values[1], -127);
    }

    #[test]
    fn per_channel_scales_differ() {
        let x = Tensor::from_vec(&[2, 2], vec![1.0, 0.5, 100.0, 50.0]);
        let q = QuantTensor::quantize(&x, 8, Granularity::PerChannel);
        assert_eq!(q.scales.len(), 2);
        assert!((q.scales[1] / q.scales[0] - 100.0).abs() < 1e-4);
        // Both rows use their full range.
        assert_eq!(q.values[0], 127);
        assert_eq!(q.values[2], 127);
    }

    #[test]
    fn zero_tensor_is_fixed_point() {
        let x = Tensor::zeros(&[4]);
        let q = QuantTensor::quantize(&x, 16, Granularity::PerTensor);
        assert!(q.values.iter().all(|&v| v == 0));
        assert_eq!(q.dequantize().data(), x.data());
    }

    #[test]
    fn static_scale_is_respected_and_clamps() {
        // Static scale smaller than data range -> saturation at qmax.
        let x = Tensor::from_vec(&[2], vec![10.0, -10.0]);
        let q = QuantTensor::quantize_static(&x, 8, &[0.05]);
        assert_eq!(q.values, vec![127, -127]);
    }

    #[test]
    fn sixteen_bit_is_much_finer_than_eight() {
        let mut rng = Rng::new(5);
        let mut x = Tensor::zeros(&[1024]);
        for v in x.data_mut().iter_mut() {
            *v = rng.heavy_tailed(1.0, 0.01, 40.0);
        }
        let e8 = x.mse(&fake_quant(&x, 8, Granularity::PerTensor));
        let e16 = x.mse(&fake_quant(&x, 16, Granularity::PerTensor));
        // 8 extra bits ≈ 2^16 lower MSE; demand at least 10^3.
        assert!(e16 * 1e3 < e8, "e8={e8} e16={e16}");
    }

    #[test]
    fn prop_roundtrip_error_bound() {
        let gen = VecGen { elem: ActivationLike::default(), min_len: 1, max_len: 64 };
        check("absmax-halfstep-bound", Config::default(), &gen, |xs| {
            let t = Tensor::from_vec(&[xs.len()], xs.clone());
            let q = QuantTensor::quantize(&t, 8, Granularity::PerTensor);
            let xh = q.dequantize();
            let step = if q.scales[0] > 0.0 { q.scales[0] } else { 0.0 };
            t.data()
                .iter()
                .zip(xh.data())
                .all(|(a, b)| (a - b).abs() <= step * 0.5 + 1e-6)
        });
    }

    #[test]
    fn prop_values_within_bits() {
        let gen = VecGen { elem: ActivationLike::default(), min_len: 1, max_len: 64 };
        for bits in [4u32, 8, 16] {
            check("absmax-range", Config { cases: 64, ..Default::default() }, &gen, |xs| {
                let t = Tensor::from_vec(&[xs.len()], xs.clone());
                let q = QuantTensor::quantize(&t, bits, Granularity::PerTensor);
                q.values.iter().all(|&v| v.abs() <= qmax(bits))
            });
        }
    }

    #[test]
    fn round_half_even_ties() {
        assert_eq!(round_half_even(0.5), 0);
        assert_eq!(round_half_even(1.5), 2);
        assert_eq!(round_half_even(2.5), 2);
        assert_eq!(round_half_even(-0.5), 0);
        assert_eq!(round_half_even(-1.5), -2);
    }

    #[test]
    fn rows_cols_for_all_arities() {
        let q = |shape: Vec<usize>| QuantTensor {
            values: vec![0; shape.iter().product()],
            shape,
            scales: vec![1.0],
            bits: 8,
            granularity: Granularity::PerTensor,
        };
        // 1-D: one row of n elements
        assert_eq!(q(vec![5]).rows(), 1);
        assert_eq!(q(vec![5]).cols(), 5);
        // 2-D
        assert_eq!(q(vec![3, 4]).rows(), 3);
        assert_eq!(q(vec![3, 4]).cols(), 4);
        // N-D: trailing dims flatten
        assert_eq!(q(vec![2, 3, 4]).rows(), 2);
        assert_eq!(q(vec![2, 3, 4]).cols(), 12);
        // degenerate shapes report their true (zero) extents
        assert_eq!(q(vec![]).cols(), 0);
        assert_eq!(q(vec![0]).cols(), 0);
        assert_eq!(q(vec![2, 0]).cols(), 0);
        assert_eq!(q(vec![2, 0]).rows(), 2);
    }

    #[test]
    fn dequantize_gen_used() {
        // keep Gen trait import exercised (generate directly)
        let mut rng = Rng::new(1);
        let g = ActivationLike::default();
        let _ = g.generate(&mut rng);
    }
}
