//! Stage 1 of QRazor: **quantization** to the base precision scenario.
//!
//! FP values are converted to high-bit integers with absolute-max
//! scaling (paper §3/§4.1): 8-bit for weights (per output channel),
//! 16-bit for activations (per tensor, *static* — scales come from a
//! calibration pass, never recomputed at inference), 8-bit for KV cache
//! (per tensor, static). This stage alone is the paper's Table 1
//! (W8A16 ≈ FP16 while W8A8 collapses); stage 2 (`crate::sdr`) then
//! compresses these integers to 4 bits.

mod absmax;
mod calibrate;

pub use absmax::*;
pub use calibrate::*;

/// How scales are shared across a tensor.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Granularity {
    /// One scale for the whole tensor (activations, KV cache).
    PerTensor,
    /// One scale per row of a 2-D tensor — rows are output channels for
    /// weight matrices stored `[out, in]` (the paper's per-channel).
    PerChannel,
}

/// Base precision presets from the paper.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct BasePrecision {
    /// Weight bits (incl. sign). Paper: 8.
    pub weight_bits: u32,
    /// Activation bits (incl. sign). Paper: 16 (8 for the W8A8 ablation).
    pub act_bits: u32,
    /// KV-cache bits (incl. sign). Paper: 8 (16 = effectively uncompressed).
    pub kv_bits: u32,
}

impl BasePrecision {
    /// W8A16 — the paper's primary base for W4A4 (KV kept FP16/A-width).
    pub const W8A16: BasePrecision =
        BasePrecision { weight_bits: 8, act_bits: 16, kv_bits: 16 };
    /// W8A16KV8 — the base for W4A4KV4.
    pub const W8A16KV8: BasePrecision =
        BasePrecision { weight_bits: 8, act_bits: 16, kv_bits: 8 };
    /// W8A8 — Table 1's collapsing ablation.
    pub const W8A8: BasePrecision =
        BasePrecision { weight_bits: 8, act_bits: 8, kv_bits: 8 };
}
