//! Per-site quantization policies — the composable successor to the
//! whole-model `Box<dyn Scheme>` configuration.
//!
//! QRazor's accuracy story is built on choosing the basis *per tensor
//! class* (8-bit basis for weights, 16-bit for activations/KV, 4- or
//! 8-bit SDR targets per operation — PAPER.md §4). A [`QuantPolicy`]
//! makes that a first-class serving axis: it resolves
//! `(layer_index, Site)` → [`SitePlan`] for **every** quantization
//! decision point in the model, so mixed-precision scenarios
//! (QLLM-style outlier-layer escalation, QServe-style progressive
//! W4A8→KV4) are expressible without writing a new scheme.
//!
//! ## Vocabulary
//!
//! * [`Site`] — one quantization decision point: the seven block
//!   linears ([`Site::Wq`] … [`Site::Down`]) plus the LM head, the
//!   activation entering a linear ([`Site::Act`]), the attention query
//!   ([`Site::Query`]) and the KV-cache rows ([`Site::KvCache`]).
//! * [`SitePlan`] — what happens at a site: stage-1 **basis bits**
//!   (8 for weights, 16 for activations, 8 for KV/Query), the stage-2
//!   **SDR target bits** (4, 8, or `None` = razoring off, plain
//!   stage-1 quantization), the razoring **group size**, and
//!   static-vs-dynamic activation **scaling**.
//! * [`LayerPlan`] — one layer's plans for all its sites, with
//!   optional per-weight-site overrides.
//! * [`QuantPolicy`] — the resolved surface [`crate::model::quantized::QuantModel::build`]
//!   consumes. Two backends:
//!   - **razor-native**: a base [`LayerPlan`] plus sparse per-layer
//!     overrides (everything the DSL below can say);
//!   - **uniform scheme**: any pre-redesign [`Scheme`] (the
//!     baselines), applied identically at every layer and site.
//!     `Box<dyn Scheme>` converts into this backend via `From`, so
//!     every old `QuantModel::build(w, Box::new(...), cal)` call site
//!     still works — and is property-tested bit-identical to the
//!     razor-native resolution for the whole QRazor family.
//!
//! ## Resolution order
//!
//! `resolve(layer, site)` looks up, in order:
//! 1. the per-layer override plan (if `layer` has one),
//! 2. the base plan;
//! and within the chosen [`LayerPlan`]:
//! 1. `weight_overrides[site]` for weight sites,
//! 2. the site's class plan (`weight` / `act` / `query` / `kv`).
//! [`Site::LmHead`] always resolves against the base plan (the head is
//! not a block layer). `None` means the site stays FP.
//!
//! ## DSL
//!
//! ```text
//! policy    := "fp16" | base clause*
//! base      := "w" W "a" A ["kv4"] ":" GROUP        (W ∈ {4,8}, A ∈ {4,8,16})
//! clause    := ";layers=" IDX ("," IDX)* ":" base'  (per-layer escalation;
//!                base' may omit ":" GROUP to inherit the base group)
//!            | ";kv=" 4 ":" GROUP                   (KV4 cache plan)
//!            | ";kv=off"                            (drop the KV plan)
//!            | ";w=" SITE ("," SITE)* ":" W [":" GROUP]
//!                                                   (per-site weight override;
//!                SITE ∈ {wq,wk,wv,wo,gate,up,down,lm_head}, applies at
//!                every layer, GROUP defaults to the base group)
//!            | ";dynamic"                           (dynamic act scaling)
//! ```
//!
//! `"w4a4kv4:16"` reproduces today's uniform preset exactly;
//! `"w4a4:16;layers=0,11:w4a8;kv=4:16"` keeps W4A4 everywhere but
//! escalates layers 0 and 11 to W4A8; `"w4a4kv4:16;w=down,wo:8"`
//! razors every weight to 4 bits except the down and output
//! projections, which stay at the 8-bit basis. Policies round-trip
//! string↔policy↔JSON ([`QuantPolicy::to_json`] /
//! [`QuantPolicy::from_json`]); malformed groups and unknown `kv`
//! suffixes are rejected with a clear error instead of silently
//! defaulting.
//!
//! [`QuantPolicy::sensitivity_escalate`] is the calibration-driven
//! builder: it ranks layers by their activation razoring error over
//! the recorded [`CalibrationData`] samples and escalates the top-k
//! most error-sensitive layers from A4 to A8. Its live-serving twin
//! is [`health`]: a drift detector over the numeric-health probes plus
//! an advisor that maps alarmed sites to the same DSL-expressible
//! escalations ([`health::advise`]).

pub mod health;

use std::collections::BTreeMap;
use std::fmt;
use std::sync::Arc;

use crate::baselines::{quant_or_razor, PackedWeight, PreparedLinear, Scheme};
use crate::model::quantized::CalibrationData;
use crate::quant::{fake_quant, Granularity, QuantTensor};
use crate::sdr::packed::PackedSdrMatrix;
use crate::sdr::razor::{qrazor_fake_quant, SdrMatrix, SdrSpec};
use crate::tensor::Tensor;
use crate::util::json::Json;

/// One quantization decision point in the transformer.
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord)]
pub enum Site {
    /// Attention query projection weight.
    Wq,
    /// Attention key projection weight.
    Wk,
    /// Attention value projection weight.
    Wv,
    /// Attention output projection weight.
    Wo,
    /// SwiGLU gate projection weight.
    Gate,
    /// SwiGLU up projection weight.
    Up,
    /// SwiGLU down projection weight.
    Down,
    /// LM head weight (resolves against the base plan; not a block
    /// layer).
    LmHead,
    /// The activation entering a linear (shared across the layer's
    /// linears, like the paper's per-tensor static scales).
    Act,
    /// The RoPE'd attention query entering Q·Kᵀ.
    Query,
    /// K/V rows entering attention and the KV cache.
    KvCache,
}

impl Site {
    /// The weight sites, in model order.
    pub const WEIGHTS: [Site; 8] = [
        Site::Wq,
        Site::Wk,
        Site::Wv,
        Site::Wo,
        Site::Gate,
        Site::Up,
        Site::Down,
        Site::LmHead,
    ];

    pub fn is_weight(self) -> bool {
        Site::WEIGHTS.contains(&self)
    }

    /// Stable lowercase key (JSON `weight_overrides` maps).
    pub fn key(self) -> &'static str {
        match self {
            Site::Wq => "wq",
            Site::Wk => "wk",
            Site::Wv => "wv",
            Site::Wo => "wo",
            Site::Gate => "gate",
            Site::Up => "up",
            Site::Down => "down",
            Site::LmHead => "lm_head",
            Site::Act => "act",
            Site::Query => "query",
            Site::KvCache => "kv",
        }
    }

    pub fn parse(s: &str) -> Option<Site> {
        Some(match s {
            "wq" => Site::Wq,
            "wk" => Site::Wk,
            "wv" => Site::Wv,
            "wo" => Site::Wo,
            "gate" => Site::Gate,
            "up" => Site::Up,
            "down" => Site::Down,
            "lm_head" => Site::LmHead,
            "act" => Site::Act,
            "query" => Site::Query,
            "kv" => Site::KvCache,
            _ => return None,
        })
    }
}

/// Static-vs-dynamic stage-1 scaling for activation-class sites.
/// Weights are always quantized offline per-channel; the field is
/// carried but ignored for weight sites.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum Scaling {
    /// Use the calibrated per-tensor static scale when one exists
    /// (QRazor's recipe).
    #[default]
    Static,
    /// Ignore calibrated scales; quantize per-tensor on the fly.
    Dynamic,
}

/// What happens at one site: basis bits, SDR target bits, group size,
/// scaling mode.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct SitePlan {
    /// Stage-1 basis precision in bits (8 for weights/KV, 16 for
    /// activations in every paper scenario).
    pub basis_bits: u32,
    /// Stage-2 SDR target bits: `Some(4)` / `Some(8)` razor to that
    /// width, `None` = razoring off (plain stage-1 quantization at the
    /// basis precision).
    pub target_bits: Option<u32>,
    /// Elements per razoring group.
    pub group: usize,
    /// Static-vs-dynamic scaling (activation-class sites only).
    pub scaling: Scaling,
}

impl SitePlan {
    pub fn new(basis_bits: u32, target_bits: Option<u32>, group: usize) -> SitePlan {
        SitePlan { basis_bits, target_bits, group, scaling: Scaling::Static }
    }

    /// Does stage 2 actually razor (target strictly below basis)?
    pub fn razors(&self) -> bool {
        self.target_bits.is_some_and(|t| t < self.basis_bits)
    }

    /// The SDR spec this plan quantizes with (`target == basis` when
    /// razoring is off, which the razor kernels treat as stage-1 only).
    pub fn spec(&self) -> SdrSpec {
        SdrSpec::new(self.basis_bits, self.target_bits.unwrap_or(self.basis_bits), self.group)
    }

    /// Honor a calibrated static scale only under [`Scaling::Static`].
    fn effective_scale(&self, s: Option<f32>) -> Option<f32> {
        match self.scaling {
            Scaling::Static => s,
            Scaling::Dynamic => None,
        }
    }

    fn validate(&self, what: &str) -> anyhow::Result<()> {
        anyhow::ensure!(
            (2..=16).contains(&self.basis_bits),
            "{what}: basis bits {} out of range 2..=16",
            self.basis_bits
        );
        if let Some(t) = self.target_bits {
            anyhow::ensure!(
                (2..=16).contains(&t) && t <= self.basis_bits,
                "{what}: target bits {t} must be in 2..=16 and <= basis {}",
                self.basis_bits
            );
        }
        validate_group(self.group, what)
    }

    fn to_json(&self) -> Json {
        Json::from_pairs(vec![
            ("basis", Json::from(self.basis_bits)),
            (
                "target",
                match self.target_bits {
                    Some(t) => Json::from(t),
                    None => Json::Null,
                },
            ),
            ("group", Json::from(self.group)),
            (
                "scaling",
                Json::from(match self.scaling {
                    Scaling::Static => "static",
                    Scaling::Dynamic => "dynamic",
                }),
            ),
        ])
    }

    fn from_json(j: &Json, what: &str) -> anyhow::Result<SitePlan> {
        let basis = j
            .req("basis")?
            .as_usize()
            .ok_or_else(|| anyhow::anyhow!("{what}: 'basis' not a number"))? as u32;
        let target = match j.get("target") {
            None | Some(Json::Null) => None,
            Some(t) => {
                Some(t.as_usize().ok_or_else(|| {
                    anyhow::anyhow!("{what}: 'target' must be a number or null")
                })? as u32)
            }
        };
        let group = j
            .req("group")?
            .as_usize()
            .ok_or_else(|| anyhow::anyhow!("{what}: 'group' not a number"))?;
        let scaling = match j.get("scaling").and_then(|s| s.as_str()) {
            None | Some("static") => Scaling::Static,
            Some("dynamic") => Scaling::Dynamic,
            Some(other) => anyhow::bail!("{what}: unknown scaling '{other}'"),
        };
        let plan = SitePlan { basis_bits: basis, target_bits: target, group, scaling };
        plan.validate(what)?;
        Ok(plan)
    }
}

/// One layer's plans for every site class. `None` = the site stays FP.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct LayerPlan {
    /// Plan for the layer's weight matrices (all seven block linears
    /// unless overridden per site below).
    pub weight: Option<SitePlan>,
    /// Sparse per-weight-site overrides (e.g. keep `Down` at 8 bits
    /// while the rest razor to 4). Keys must be weight sites.
    pub weight_overrides: BTreeMap<Site, SitePlan>,
    /// Plan for activations entering the layer's linears.
    pub act: Option<SitePlan>,
    /// Plan for the attention query entering Q·Kᵀ.
    pub query: Option<SitePlan>,
    /// Plan for K/V rows (attention operands + the packed KV cache).
    pub kv: Option<SitePlan>,
}

impl LayerPlan {
    /// Resolve a site within this layer (see the module doc for the
    /// resolution order).
    pub fn site(&self, site: Site) -> Option<SitePlan> {
        match site {
            s if s.is_weight() => self.weight_overrides.get(&s).copied().or(self.weight),
            Site::Act => self.act,
            Site::Query => self.query,
            Site::KvCache => self.kv,
            _ => unreachable!("weight sites handled above"),
        }
    }

    fn validate(&self) -> anyhow::Result<()> {
        if let Some(w) = &self.weight {
            w.validate("weight plan")?;
        }
        for (site, p) in &self.weight_overrides {
            anyhow::ensure!(
                site.is_weight(),
                "weight_overrides key '{}' is not a weight site",
                site.key()
            );
            p.validate(&format!("weight override '{}'", site.key()))?;
        }
        if let Some(a) = &self.act {
            a.validate("act plan")?;
        }
        if let Some(q) = &self.query {
            q.validate("query plan")?;
        }
        if let Some(k) = &self.kv {
            k.validate("kv plan")?;
        }
        Ok(())
    }

    fn to_json(&self) -> Json {
        let opt = |p: &Option<SitePlan>| p.map(|p| p.to_json()).unwrap_or(Json::Null);
        let mut j = Json::from_pairs(vec![
            ("weight", opt(&self.weight)),
            ("act", opt(&self.act)),
            ("query", opt(&self.query)),
            ("kv", opt(&self.kv)),
        ]);
        if !self.weight_overrides.is_empty() {
            let mut m = Json::obj();
            for (site, p) in &self.weight_overrides {
                m.set(site.key(), p.to_json());
            }
            j.set("weight_overrides", m);
        }
        j
    }

    fn from_json(j: &Json) -> anyhow::Result<LayerPlan> {
        let opt = |key: &str| -> anyhow::Result<Option<SitePlan>> {
            match j.get(key) {
                None | Some(Json::Null) => Ok(None),
                Some(p) => Ok(Some(SitePlan::from_json(p, key)?)),
            }
        };
        let mut weight_overrides = BTreeMap::new();
        if let Some(Json::Obj(m)) = j.get("weight_overrides") {
            for (k, v) in m {
                let site = Site::parse(k)
                    .ok_or_else(|| anyhow::anyhow!("unknown weight_overrides site '{k}'"))?;
                weight_overrides.insert(site, SitePlan::from_json(v, k)?);
            }
        }
        let plan = LayerPlan {
            weight: opt("weight")?,
            weight_overrides,
            act: opt("act")?,
            query: opt("query")?,
            kv: opt("kv")?,
        };
        plan.validate()?;
        Ok(plan)
    }
}

/// The razor-native policy body: a base plan plus sparse per-layer
/// overrides.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct RazorPolicy {
    pub base: LayerPlan,
    pub overrides: BTreeMap<usize, LayerPlan>,
}

impl RazorPolicy {
    /// The effective plan for a block layer.
    pub fn layer(&self, layer: usize) -> &LayerPlan {
        self.overrides.get(&layer).unwrap_or(&self.base)
    }

    /// Resolve `(layer, site)`. [`Site::LmHead`] ignores layer
    /// overrides.
    pub fn resolve(&self, layer: usize, site: Site) -> Option<SitePlan> {
        if site == Site::LmHead {
            return self.base.site(site);
        }
        self.layer(layer).site(site)
    }

    /// The activation plan governing the linear at `(layer, site)`:
    /// the LM head always reads the base plan (it is not a block
    /// layer); every other site reads its layer's resolution. The one
    /// definition shared by weight prep, the act fallback, basis-bit
    /// derivation, and static-scale suppression — so the packed
    /// operand's `act_spec` can never desynchronize from the fallback
    /// transform.
    fn act_plan(&self, layer: usize, site: Site) -> Option<SitePlan> {
        if site == Site::LmHead {
            self.base.site(Site::Act)
        } else {
            self.resolve(layer, Site::Act)
        }
    }

    fn validate(&self) -> anyhow::Result<()> {
        self.base.validate()?;
        for (li, p) in &self.overrides {
            p.validate().map_err(|e| anyhow::anyhow!("layer {li}: {e}"))?;
        }
        Ok(())
    }
}

enum Backend {
    /// A pre-redesign [`Scheme`] applied uniformly at every layer and
    /// site (all the baselines).
    Uniform(Arc<dyn Scheme>),
    /// Razor-native per-site resolution.
    Razor(RazorPolicy),
}

impl Clone for Backend {
    fn clone(&self) -> Backend {
        match self {
            Backend::Uniform(s) => Backend::Uniform(Arc::clone(s)),
            Backend::Razor(r) => Backend::Razor(r.clone()),
        }
    }
}

/// A complete quantization policy — what [`crate::model::quantized::QuantModel::build`]
/// consumes. See the module doc.
#[derive(Clone)]
pub struct QuantPolicy {
    backend: Backend,
}

// Hand-written because `Arc<dyn Scheme>` has no `Debug`.
impl fmt::Debug for QuantPolicy {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "QuantPolicy({})", self.name())
    }
}

impl fmt::Display for QuantPolicy {
    /// Canonical DSL form for razor-native policies (round-trips
    /// through [`QuantPolicy::parse`] for every DSL-expressible
    /// policy); the scheme name for uniform scheme backends.
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match &self.backend {
            Backend::Uniform(s) => write!(f, "{}", s.name()),
            Backend::Razor(r) => write!(f, "{}", razor_dsl(r)),
        }
    }
}

impl From<Box<dyn Scheme>> for QuantPolicy {
    fn from(scheme: Box<dyn Scheme>) -> QuantPolicy {
        QuantPolicy::uniform(scheme)
    }
}

/// Concrete boxed schemes convert too: `Box<QRazor>`, `Box<Fp16>`, …
/// — unsized coercion does not happen through a generic parameter, so
/// without this blanket impl every pre-redesign
/// `QuantModel::build(&w, Box::new(Scheme), &cal)` call site would
/// stop compiling. (No overlap with the `Box<dyn Scheme>` impl above:
/// this one requires a sized `S`.)
impl<S: Scheme + 'static> From<Box<S>> for QuantPolicy {
    fn from(scheme: Box<S>) -> QuantPolicy {
        let arc: Arc<dyn Scheme> = Arc::from(scheme);
        QuantPolicy { backend: Backend::Uniform(arc) }
    }
}

impl QuantPolicy {
    /// Wrap a pre-redesign scheme as a uniform policy: the scheme's
    /// hooks run unchanged at every layer and site.
    pub fn uniform(scheme: Box<dyn Scheme>) -> QuantPolicy {
        QuantPolicy { backend: Backend::Uniform(Arc::from(scheme)) }
    }

    /// Build from a razor-native body.
    pub fn from_razor(r: RazorPolicy) -> anyhow::Result<QuantPolicy> {
        r.validate()?;
        Ok(QuantPolicy { backend: Backend::Razor(r) })
    }

    /// The FP16 identity policy.
    pub fn fp16() -> QuantPolicy {
        QuantPolicy { backend: Backend::Razor(RazorPolicy::default()) }
    }

    /// Uniform razor-native presets mirroring the old constructors.
    pub fn w4a4(g: usize) -> QuantPolicy {
        QuantPolicy::parse(&format!("w4a4:{g}")).expect("valid preset")
    }

    pub fn w4a4kv4(g: usize) -> QuantPolicy {
        QuantPolicy::parse(&format!("w4a4kv4:{g}")).expect("valid preset")
    }

    pub fn w4a8(g: usize) -> QuantPolicy {
        QuantPolicy::parse(&format!("w4a8:{g}")).expect("valid preset")
    }

    pub fn w4a8kv4(g: usize) -> QuantPolicy {
        QuantPolicy::parse(&format!("w4a8kv4:{g}")).expect("valid preset")
    }

    /// Err when a per-layer override names a layer the model does not
    /// have — otherwise the override would be a silent no-op, exactly
    /// the kind of typo (`layers=12` on a 12-layer model) the DSL is
    /// supposed to surface. Uniform scheme backends have no overrides
    /// and always pass.
    pub fn check_layers(&self, layers: usize) -> anyhow::Result<()> {
        if let Some(r) = self.razor() {
            for &li in r.overrides.keys() {
                anyhow::ensure!(
                    li < layers,
                    "policy '{}' overrides layer {li}, but the model has {layers} \
                     layers (valid indices 0..={})",
                    self,
                    layers.saturating_sub(1)
                );
            }
        }
        Ok(())
    }

    /// The razor-native body, when this policy has one.
    pub fn razor(&self) -> Option<&RazorPolicy> {
        match &self.backend {
            Backend::Razor(r) => Some(r),
            Backend::Uniform(_) => None,
        }
    }

    /// Human-readable policy name (canonical DSL for razor policies,
    /// the scheme's own name for uniform scheme backends).
    pub fn name(&self) -> String {
        self.to_string()
    }

    /// Razor-native resolution of `(layer, site)`; `None` for uniform
    /// scheme backends (their hooks are opaque) and for FP sites.
    pub fn resolve(&self, layer: usize, site: Site) -> Option<SitePlan> {
        match &self.backend {
            Backend::Razor(r) => r.resolve(layer, site),
            Backend::Uniform(_) => None,
        }
    }

    /// Does the prepared linear at `(layer, site)` carry a packed SDR
    /// weight operand, and with which `(weight_spec, act_spec)`? The
    /// single gate [`QuantPolicy::prep_linear`] and the packed
    /// checkpoint reader (`crate::artifact`) share: a weight razoring
    /// to 4 bits paired with an activation razoring to 4 or 8 bits
    /// (the paper's W4A4 / W4A8 scenarios). `None` for uniform scheme
    /// backends and for unpacked sites.
    pub fn packs_weight(&self, layer: usize, site: Site) -> Option<(SdrSpec, SdrSpec)> {
        let r = self.razor()?;
        let wp = r.resolve(layer, site)?;
        let ap = r.act_plan(layer, site)?;
        (wp.target_bits == Some(4)
            && wp.razors()
            && matches!(ap.target_bits, Some(4) | Some(8))
            && ap.razors())
        .then(|| (wp.spec(), ap.spec()))
    }

    /// Can this policy be embedded in — and reconstructed from — a
    /// packed checkpoint manifest? True exactly for razor-native
    /// policies; uniform scheme backends serialize as an opaque name
    /// ([`QuantPolicy::to_json`]) and cannot round-trip.
    pub fn artifact_serializable(&self) -> bool {
        matches!(self.backend, Backend::Razor(_))
    }

    // ---- model-facing behavior ------------------------------------------

    /// Prepare one linear at `(layer, site)`. Razor backends attach the
    /// packed nibble weight whenever the weight razors to 4 bits and
    /// the activation razors to 4 or 8 (the paper's W4A4 / W4A8
    /// scenarios — A4 pairs with the nibble GEMM, A8 with the
    /// byte-coded one; the gate is [`QuantPolicy::packs_weight`]).
    pub fn prep_linear(
        &self,
        layer: usize,
        site: Site,
        w: &Tensor<f32>,
        calib: Option<&Tensor<f32>>,
    ) -> PreparedLinear {
        debug_assert!(site.is_weight(), "prep_linear at a non-weight site");
        match &self.backend {
            Backend::Uniform(s) => s.prep_linear(w, calib),
            Backend::Razor(r) => {
                let wp = r.resolve(layer, site);
                let weight = match wp {
                    None => w.clone(),
                    Some(p) if !p.razors() => fake_quant(w, p.basis_bits, Granularity::PerChannel),
                    Some(p) => qrazor_fake_quant(w, p.spec(), Granularity::PerChannel),
                };
                let packed = self.packs_weight(layer, site).map(|(wspec, act_spec)| {
                    let q = QuantTensor::quantize(w, wspec.base_bits, Granularity::PerChannel);
                    PackedWeight {
                        weight: PackedSdrMatrix::from_matrix(&SdrMatrix::compress(wspec, &q)),
                        act_spec,
                    }
                });
                PreparedLinear { weight, act_override: None, packed }
            }
        }
    }

    /// The fallback activation transform for a linear at `(layer,
    /// site)` — what [`PreparedLinear::forward_with_packed`] runs when
    /// no packed operand (and no per-layer override) applies.
    pub fn act(
        &self,
        layer: usize,
        site: Site,
        x: &Tensor<f32>,
        static_scale: Option<f32>,
    ) -> Tensor<f32> {
        match &self.backend {
            Backend::Uniform(s) => s.act(x, static_scale),
            Backend::Razor(r) => match r.act_plan(layer, site) {
                None => x.clone(),
                Some(p) => quant_or_razor(x, p.spec(), p.effective_scale(static_scale)),
            },
        }
    }

    /// Stage-1 basis bits the static activation scale for `(layer,
    /// site)` should be derived at (16 unless a plan says otherwise).
    pub fn act_basis_bits(&self, layer: usize, site: Site) -> u32 {
        let plan = match &self.backend {
            Backend::Razor(r) => r.act_plan(layer, site),
            Backend::Uniform(_) => None,
        };
        plan.map(|p| p.basis_bits).unwrap_or(16)
    }

    /// Suppress a calibrated static scale when the site's plan scales
    /// dynamically (uniform scheme backends pass it through — their
    /// hooks decide for themselves, exactly as before the redesign).
    pub fn effective_scale(&self, layer: usize, site: Site, s: Option<f32>) -> Option<f32> {
        match &self.backend {
            Backend::Uniform(_) => s,
            Backend::Razor(r) => match r.act_plan(layer, site) {
                None => s,
                Some(p) => p.effective_scale(s),
            },
        }
    }

    /// Like [`QuantPolicy::effective_scale`] but for the Query site:
    /// the packed-attention `q_scale` must also honor dynamic scaling
    /// (a dynamic query plan drops the calibrated scale and falls back
    /// to the staged attention path).
    pub fn query_effective_scale(&self, layer: usize, s: Option<f32>) -> Option<f32> {
        match &self.backend {
            Backend::Uniform(_) => s,
            Backend::Razor(r) => match r.resolve(layer, Site::Query) {
                None => s,
                Some(p) => p.effective_scale(s),
            },
        }
    }

    /// Transform K/V rows entering attention (and an FP decode cache).
    pub fn kv_transform(&self, layer: usize, x: &Tensor<f32>, s: Option<f32>) -> Tensor<f32> {
        match &self.backend {
            Backend::Uniform(sch) => sch.kv(x, s),
            Backend::Razor(r) => match r.resolve(layer, Site::KvCache) {
                None => x.clone(),
                Some(p) => quant_or_razor(x, p.spec(), p.effective_scale(s)),
            },
        }
    }

    /// Transform the attention query entering Q·Kᵀ.
    pub fn query_transform(&self, layer: usize, x: &Tensor<f32>, s: Option<f32>) -> Tensor<f32> {
        match &self.backend {
            Backend::Uniform(sch) => sch.kv(x, s),
            Backend::Razor(r) => match r.resolve(layer, Site::Query) {
                None => x.clone(),
                Some(p) => quant_or_razor(x, p.spec(), p.effective_scale(s)),
            },
        }
    }

    /// Basis bits for the layer's KV/Query static scales (8 unless a
    /// plan says otherwise).
    pub fn kv_basis_bits(&self, layer: usize) -> u32 {
        match &self.backend {
            Backend::Uniform(_) => 8,
            Backend::Razor(r) => r
                .resolve(layer, Site::KvCache)
                .or_else(|| r.resolve(layer, Site::Query))
                .map(|p| p.basis_bits)
                .unwrap_or(8),
        }
    }

    /// Does any layer quantize its KV cache?
    pub fn quantizes_kv(&self) -> bool {
        match &self.backend {
            Backend::Uniform(s) => s.quantizes_kv(),
            Backend::Razor(r) => {
                r.base.kv.is_some() || r.overrides.values().any(|p| p.kv.is_some())
            }
        }
    }

    /// Per-layer specs for a packed SDR decode cache, or `None` when
    /// the policy should use an FP cache (no KV plan, a layer whose
    /// plan cannot pack to 4-bit planes, a group that doesn't divide
    /// `kv_dim`, or a **dynamically scaled** KV plan — the packed
    /// cache compresses rows online at calibrated *static* scales, so
    /// a dynamic plan must stay on the FP path where
    /// [`QuantPolicy::kv_transform`] honors it; otherwise eval and
    /// serve would quantize the same policy differently). Mixed
    /// per-layer groups are supported; mixed FP/SDR layers fall back
    /// to the FP cache, where `kv_transform` still applies each
    /// layer's plan.
    pub fn kv_cache_specs(
        &self,
        layers: usize,
        kv_dim: usize,
        fallback_group: usize,
    ) -> Option<Vec<SdrSpec>> {
        match &self.backend {
            Backend::Uniform(s) => {
                if s.quantizes_kv() && fallback_group >= 1 && kv_dim % fallback_group == 0 {
                    Some(vec![SdrSpec::new(8, 4, fallback_group); layers])
                } else {
                    None
                }
            }
            Backend::Razor(r) => {
                let mut specs = Vec::with_capacity(layers);
                for li in 0..layers {
                    let p = r.resolve(li, Site::KvCache)?;
                    if p.target_bits != Some(4)
                        || !p.razors()
                        || p.scaling == Scaling::Dynamic
                        || kv_dim % p.group != 0
                    {
                        return None;
                    }
                    specs.push(p.spec());
                }
                if specs.is_empty() {
                    return None;
                }
                Some(specs)
            }
        }
    }

    /// The SDR spec the layer's query should be razored with before
    /// the decompression-free packed KV attention; `None` keeps the
    /// layer on the reconstruct-then-multiply path.
    pub fn sdr_query_spec(&self, layer: usize) -> Option<SdrSpec> {
        match &self.backend {
            Backend::Uniform(s) => s.sdr_query_spec(),
            Backend::Razor(r) => match r.resolve(layer, Site::Query) {
                Some(p) if p.target_bits == Some(4) && p.razors() => Some(p.spec()),
                _ => None,
            },
        }
    }

    // ---- calibration-driven building ------------------------------------

    /// Total activation razoring error of this policy over the
    /// calibration samples: for each block layer and each recorded
    /// activation site, the relative Frobenius error of razoring the
    /// sample under the layer's act plan. The sensitivity builder
    /// ranks layers by their share of this sum.
    pub fn act_calibration_error(&self, cal: &CalibrationData, layers: usize) -> f64 {
        (0..layers).map(|li| self.layer_act_error(cal, li)).sum()
    }

    fn layer_act_error(&self, cal: &CalibrationData, layer: usize) -> f64 {
        let Some(r) = self.razor() else { return 0.0 };
        let Some(plan) = r.resolve(layer, Site::Act) else { return 0.0 };
        let mut err = 0.0;
        for name in ["attn_in", "attn_out", "ffn_in", "ffn_down_in"] {
            if let Some(x) = cal.sample(&format!("l{layer}.{name}")) {
                let q = quant_or_razor(x, plan.spec(), None);
                err += crate::baselines::rel_error(x, &q);
            }
        }
        err
    }

    /// Calibration-driven mixed-precision builder: rank the block
    /// layers by their activation razoring error over `cal`'s recorded
    /// samples and escalate the `top_k` most error-sensitive layers
    /// from a 4-bit to an 8-bit activation target (W stays razored;
    /// the paper's W4A4 → W4A8 move, applied only where it pays).
    /// Errs on uniform scheme backends and on policies whose base act
    /// plan is not A4.
    pub fn sensitivity_escalate(
        &self,
        cal: &CalibrationData,
        layers: usize,
        top_k: usize,
    ) -> anyhow::Result<QuantPolicy> {
        let r = self
            .razor()
            .ok_or_else(|| anyhow::anyhow!("sensitivity builder needs a razor-native policy"))?;
        let base_act = r
            .base
            .act
            .ok_or_else(|| anyhow::anyhow!("policy has no activation plan to escalate"))?;
        anyhow::ensure!(
            base_act.target_bits == Some(4),
            "sensitivity escalation starts from an A4 policy, got target {:?}",
            base_act.target_bits
        );
        let mut scored: Vec<(usize, f64)> =
            (0..layers).map(|li| (li, self.layer_act_error(cal, li))).collect();
        // Highest error first; ties break on the lower layer index so
        // the escalation is deterministic.
        scored.sort_by(|a, b| b.1.partial_cmp(&a.1).unwrap().then(a.0.cmp(&b.0)));
        let mut out = r.clone();
        for &(li, _) in scored.iter().take(top_k.min(layers)) {
            let mut plan = out.layer(li).clone();
            if let Some(a) = plan.act.as_mut() {
                if a.target_bits == Some(4) {
                    a.target_bits = Some(8);
                }
            }
            out.overrides.insert(li, plan);
        }
        QuantPolicy::from_razor(out)
    }

    // ---- parsing / serialization ----------------------------------------

    /// Parse the policy DSL (see the module doc for the grammar).
    /// Rejects malformed group sizes and unknown `kv` suffixes with a
    /// clear error instead of silently defaulting.
    pub fn parse(s: &str) -> anyhow::Result<QuantPolicy> {
        let s = s.trim();
        anyhow::ensure!(!s.is_empty(), "empty policy string");
        let mut segments = s.split(';');
        let base_str = segments.next().unwrap().trim();
        if base_str == "fp16" {
            let rest: Vec<&str> = segments.collect();
            anyhow::ensure!(
                rest.iter().all(|c| c.trim().is_empty()),
                "fp16 takes no clauses, got '{}'",
                rest.join(";")
            );
            return Ok(QuantPolicy::fp16());
        }
        let (base_preset, base_group) = parse_base(base_str)?;
        let mut base = base_preset.layer_plan(base_group);
        let mut layer_clauses: Vec<(Vec<usize>, Preset, usize)> = Vec::new();
        let mut kv_clause: Option<Option<SitePlan>> = None;
        let mut weight_clauses: BTreeMap<Site, SitePlan> = BTreeMap::new();
        let mut dynamic = false;
        for clause in segments {
            let clause = clause.trim();
            anyhow::ensure!(!clause.is_empty(), "empty clause in policy '{s}'");
            if clause == "dynamic" {
                dynamic = true;
            } else if let Some(rest) = clause.strip_prefix("kv=") {
                anyhow::ensure!(kv_clause.is_none(), "duplicate kv clause");
                if rest == "off" {
                    kv_clause = Some(None);
                } else {
                    let (bits, group) = rest.split_once(':').ok_or_else(|| {
                        anyhow::anyhow!("kv clause format: kv=4:GROUP or kv=off, got 'kv={rest}'")
                    })?;
                    anyhow::ensure!(
                        bits == "4",
                        "unsupported kv target '{bits}' (the packed KV cache is KV4)"
                    );
                    let group = parse_group(group)?;
                    kv_clause = Some(Some(SitePlan::new(8, Some(4), group)));
                }
            } else if let Some(rest) = clause.strip_prefix("w=") {
                let (list, spec) = rest.split_once(':').ok_or_else(|| {
                    anyhow::anyhow!("weight clause format: w=SITE[,SITE]:BITS[:GROUP]")
                })?;
                let (bits_str, group) = match spec.split_once(':') {
                    Some((b, g)) => (b.trim(), parse_group(g)?),
                    None => (spec.trim(), base_group),
                };
                let bits: u32 = match bits_str {
                    "4" => 4,
                    "8" => 8,
                    other => anyhow::bail!(
                        "unsupported weight override width '{other}' in clause '{clause}' \
                         (expected 4 or 8)"
                    ),
                };
                let plan = SitePlan::new(8, (bits < 8).then_some(bits), group);
                for part in list.split(',') {
                    let key = part.trim();
                    let site = Site::parse(key).filter(|s| s.is_weight()).ok_or_else(|| {
                        anyhow::anyhow!(
                            "'{key}' is not a weight site (expected wq, wk, wv, wo, gate, \
                             up, down, or lm_head)"
                        )
                    })?;
                    anyhow::ensure!(
                        weight_clauses.insert(site, plan).is_none(),
                        "duplicate weight override for site '{key}'"
                    );
                }
            } else if let Some(rest) = clause.strip_prefix("layers=") {
                let (list, preset_str) = rest.split_once(':').ok_or_else(|| {
                    anyhow::anyhow!("layer clause format: layers=I,J:PRESET[:GROUP]")
                })?;
                let mut idx = Vec::new();
                for part in list.split(',') {
                    let i: usize = part.trim().parse().map_err(|_| {
                        anyhow::anyhow!("bad layer index '{part}' in clause '{clause}'")
                    })?;
                    idx.push(i);
                }
                anyhow::ensure!(!idx.is_empty(), "empty layer list in clause '{clause}'");
                let (preset, group) = match preset_str.split_once(':') {
                    Some((p, g)) => (Preset::parse(p)?, parse_group(g)?),
                    None => (Preset::parse(preset_str)?, base_group),
                };
                layer_clauses.push((idx, preset, group));
            } else {
                anyhow::bail!(
                    "unknown policy clause '{clause}' (expected layers=…, kv=…, w=…, or \
                     dynamic)"
                );
            }
        }
        // Assemble: kv clause overrides the base preset's kv suffix;
        // layer overrides inherit whatever kv plan the base ends up
        // with unless their own preset carries a kv4 suffix.
        if let Some(kv) = kv_clause {
            base.kv = kv;
            base.query = kv;
        }
        // w= overrides are policy-wide: escalated layers inherit them
        // too, so a pinned site (say down at 8 bits) stays pinned no
        // matter which preset governs the layer.
        base.weight_overrides = weight_clauses;
        let mut overrides = BTreeMap::new();
        for (idx, preset, group) in layer_clauses {
            for li in idx {
                let mut plan = preset.layer_plan(group);
                if !preset.kv4 {
                    plan.kv = base.kv;
                    plan.query = base.query;
                }
                plan.weight_overrides = base.weight_overrides.clone();
                overrides.insert(li, plan);
            }
        }
        let mut r = RazorPolicy { base, overrides };
        if dynamic {
            for plan in std::iter::once(&mut r.base).chain(r.overrides.values_mut()) {
                for p in [&mut plan.act, &mut plan.query, &mut plan.kv] {
                    if let Some(p) = p.as_mut() {
                        p.scaling = Scaling::Dynamic;
                    }
                }
            }
        }
        QuantPolicy::from_razor(r)
    }

    /// JSON manifest form (lossless for razor-native policies; uniform
    /// scheme backends serialize as an opaque name and cannot be
    /// reconstructed from JSON).
    pub fn to_json(&self) -> Json {
        match &self.backend {
            Backend::Uniform(s) => Json::from_pairs(vec![
                ("kind", Json::from("scheme")),
                ("name", Json::from(s.name())),
            ]),
            Backend::Razor(r) => {
                let mut j = Json::from_pairs(vec![
                    ("kind", Json::from("razor")),
                    ("name", Json::from(self.name())),
                    ("base", r.base.to_json()),
                ]);
                if !r.overrides.is_empty() {
                    let mut m = Json::obj();
                    for (li, plan) in &r.overrides {
                        m.set(&li.to_string(), plan.to_json());
                    }
                    j.set("overrides", m);
                }
                j
            }
        }
    }

    pub fn from_json(j: &Json) -> anyhow::Result<QuantPolicy> {
        match j.req("kind")?.as_str() {
            Some("razor") => {
                let base = LayerPlan::from_json(j.req("base")?)?;
                let mut overrides = BTreeMap::new();
                if let Some(Json::Obj(m)) = j.get("overrides") {
                    for (k, v) in m {
                        let li: usize = k
                            .parse()
                            .map_err(|_| anyhow::anyhow!("bad override layer index '{k}'"))?;
                        overrides.insert(li, LayerPlan::from_json(v)?);
                    }
                }
                QuantPolicy::from_razor(RazorPolicy { base, overrides })
            }
            Some("scheme") => anyhow::bail!(
                "scheme-backed policy '{}' is not reconstructible from JSON; \
                 rebuild it programmatically or use a razor policy",
                j.get("name").and_then(|n| n.as_str()).unwrap_or("?")
            ),
            Some(other) => anyhow::bail!("unknown policy kind '{other}'"),
            None => anyhow::bail!("policy 'kind' must be a string"),
        }
    }
}

/// A parsed `w{W}a{A}[kv4]` token.
#[derive(Clone, Copy, Debug)]
struct Preset {
    w_target: u32,
    a_target: u32,
    kv4: bool,
}

impl Preset {
    fn parse(tok: &str) -> anyhow::Result<Preset> {
        let tok = tok.trim();
        let rest = tok
            .strip_prefix('w')
            .ok_or_else(|| anyhow::anyhow!("unknown policy preset '{tok}' (expected wXaY[kv4])"))?;
        let a_pos = rest
            .find('a')
            .ok_or_else(|| anyhow::anyhow!("preset '{tok}' is missing the activation width"))?;
        let w_target: u32 = rest[..a_pos]
            .parse()
            .map_err(|_| anyhow::anyhow!("bad weight width in preset '{tok}'"))?;
        let after_a = &rest[a_pos + 1..];
        let (a_str, kv_str) = match after_a.find(|c: char| !c.is_ascii_digit()) {
            Some(i) => after_a.split_at(i),
            None => (after_a, ""),
        };
        let a_target: u32 = a_str
            .parse()
            .map_err(|_| anyhow::anyhow!("bad activation width in preset '{tok}'"))?;
        let kv4 = match kv_str {
            "" => false,
            "kv4" => true,
            other => anyhow::bail!(
                "unknown kv suffix '{other}' in preset '{tok}' (only 'kv4' is supported)"
            ),
        };
        anyhow::ensure!(
            matches!(w_target, 4 | 8),
            "unsupported weight width w{w_target} (the 8-bit basis razors to w4 or stays w8)"
        );
        anyhow::ensure!(
            matches!(a_target, 4 | 8 | 16),
            "unsupported activation width a{a_target} (expected a4, a8 or a16)"
        );
        Ok(Preset { w_target, a_target, kv4 })
    }

    /// Expand into a layer plan at `group` (W8 basis, A16 basis, KV8
    /// basis — the paper's base precision scenario).
    fn layer_plan(&self, group: usize) -> LayerPlan {
        let weight = SitePlan::new(
            8,
            if self.w_target < 8 { Some(self.w_target) } else { None },
            group,
        );
        let act = SitePlan::new(
            16,
            if self.a_target < 16 { Some(self.a_target) } else { None },
            group,
        );
        let kv = self.kv4.then(|| SitePlan::new(8, Some(4), group));
        LayerPlan {
            weight: Some(weight),
            weight_overrides: BTreeMap::new(),
            act: Some(act),
            query: kv,
            kv,
        }
    }
}

fn parse_base(s: &str) -> anyhow::Result<(Preset, usize)> {
    let (kind, g) = s
        .split_once(':')
        .ok_or_else(|| anyhow::anyhow!("policy format: PRESET:GROUP, got '{s}'"))?;
    Ok((Preset::parse(kind)?, parse_group(g)?))
}

fn parse_group(g: &str) -> anyhow::Result<usize> {
    let group: usize = g
        .trim()
        .parse()
        .map_err(|_| anyhow::anyhow!("malformed group size '{g}' (expected an integer)"))?;
    validate_group(group, "group size")?;
    Ok(group)
}

fn validate_group(group: usize, what: &str) -> anyhow::Result<()> {
    anyhow::ensure!(
        (1..=1024).contains(&group),
        "{what}: razoring group {group} out of range 1..=1024"
    );
    Ok(())
}

/// Canonical DSL for a razor body (see [`QuantPolicy`]'s `Display`).
fn razor_dsl(r: &RazorPolicy) -> String {
    let (Some(w), Some(a)) = (r.base.weight, r.base.act) else {
        return "fp16".to_string();
    };
    let group = w.group;
    let wt = w.target_bits.unwrap_or(w.basis_bits);
    let at = a.target_bits.unwrap_or(a.basis_bits);
    // kv as the preset suffix when it matches the canonical KV4 shape
    // at the base group, otherwise as an explicit clause.
    let kv_suffix = matches!(
        r.base.kv,
        Some(p) if p.basis_bits == 8 && p.target_bits == Some(4) && p.group == group
    );
    let mut s = format!("w{wt}a{at}{}:{group}", if kv_suffix { "kv4" } else { "" });
    if let (false, Some(p)) = (kv_suffix, r.base.kv) {
        s.push_str(&format!(";kv={}:{}", p.target_bits.unwrap_or(p.basis_bits), p.group));
    }
    // per-site weight overrides, grouped by identical bits[:group]
    // token in site order (the base map is a BTreeMap, so this is
    // deterministic and the canonical form re-parses to itself)
    let mut wtoks: Vec<(String, Vec<&'static str>)> = Vec::new();
    for (site, p) in &r.base.weight_overrides {
        let bits = p.target_bits.unwrap_or(p.basis_bits);
        let tok = if p.group == group {
            format!("{bits}")
        } else {
            format!("{bits}:{}", p.group)
        };
        match wtoks.iter_mut().find(|(t, _)| *t == tok) {
            Some((_, keys)) => keys.push(site.key()),
            None => wtoks.push((tok, vec![site.key()])),
        }
    }
    for (tok, keys) in wtoks {
        s.push_str(&format!(";w={}:{tok}", keys.join(",")));
    }
    // group override layers by identical token, preserving layer order
    let mut tokens: Vec<(String, Vec<usize>)> = Vec::new();
    for (&li, plan) in &r.overrides {
        if plan == &r.base {
            continue;
        }
        let tok = override_token(plan, &r.base, group);
        match tokens.iter_mut().find(|(t, _)| *t == tok) {
            Some((_, idx)) => idx.push(li),
            None => tokens.push((tok, vec![li])),
        }
    }
    for (tok, idx) in tokens {
        let list: Vec<String> = idx.iter().map(|i| i.to_string()).collect();
        s.push_str(&format!(";layers={}:{tok}", list.join(",")));
    }
    if r.base.act.is_some_and(|p| p.scaling == Scaling::Dynamic) {
        s.push_str(";dynamic");
    }
    s
}

fn override_token(plan: &LayerPlan, base: &LayerPlan, base_group: usize) -> String {
    let wt = plan
        .weight
        .map(|p| p.target_bits.unwrap_or(p.basis_bits))
        .unwrap_or(8);
    let at = plan.act.map(|p| p.target_bits.unwrap_or(p.basis_bits)).unwrap_or(16);
    let kv4 = plan.kv != base.kv
        && matches!(
            plan.kv,
            Some(p) if p.basis_bits == 8 && p.target_bits == Some(4)
        );
    let group = plan.weight.map(|p| p.group).unwrap_or(base_group);
    let mut tok = format!("w{wt}a{at}{}", if kv4 { "kv4" } else { "" });
    if group != base_group {
        tok.push_str(&format!(":{group}"));
    }
    tok
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn presets_reproduce_the_old_scheme_strings_exactly() {
        for s in ["fp16", "w4a4:16", "w4a4kv4:16", "w4a8:16", "w4a8kv4:16", "w4a4kv4:32"] {
            let p = QuantPolicy::parse(s).unwrap();
            assert_eq!(p.to_string(), s, "canonical form must match the preset string");
            // and the canonical form re-parses to the same structure
            let again = QuantPolicy::parse(&p.to_string()).unwrap();
            assert_eq!(p.razor(), again.razor());
        }
    }

    #[test]
    fn preset_plans_mirror_the_qrazor_constructors() {
        let p = QuantPolicy::parse("w4a4kv4:16").unwrap();
        let w = p.resolve(0, Site::Wq).unwrap();
        assert_eq!((w.basis_bits, w.target_bits, w.group), (8, Some(4), 16));
        let a = p.resolve(1, Site::Act).unwrap();
        assert_eq!((a.basis_bits, a.target_bits, a.group), (16, Some(4), 16));
        let kv = p.resolve(0, Site::KvCache).unwrap();
        assert_eq!((kv.basis_bits, kv.target_bits, kv.group), (8, Some(4), 16));
        assert_eq!(p.resolve(0, Site::Query), Some(kv));
        assert!(p.quantizes_kv());
        assert_eq!(p.sdr_query_spec(0), Some(SdrSpec::new(8, 4, 16)));
        // w4a4 without the suffix: KV stays FP
        let p = QuantPolicy::parse("w4a4:16").unwrap();
        assert!(p.resolve(0, Site::KvCache).is_none());
        assert!(!p.quantizes_kv());
        assert!(p.sdr_query_spec(0).is_none());
        // a16 ablation: razoring off for activations
        let p = QuantPolicy::parse("w4a16:8").unwrap();
        let a = p.resolve(0, Site::Act).unwrap();
        assert_eq!(a.target_bits, None);
        assert!(!a.razors());
    }

    #[test]
    fn mixed_policy_escalates_named_layers_only() {
        let p = QuantPolicy::parse("w4a4:16;layers=0,11:w4a8;kv=4:16").unwrap();
        assert_eq!(p.resolve(0, Site::Act).unwrap().target_bits, Some(8));
        assert_eq!(p.resolve(11, Site::Act).unwrap().target_bits, Some(8));
        assert_eq!(p.resolve(5, Site::Act).unwrap().target_bits, Some(4));
        // weights stay W4 everywhere; kv clause applies to all layers
        for li in [0usize, 5, 11] {
            assert_eq!(p.resolve(li, Site::Wo).unwrap().target_bits, Some(4));
            let kv = p.resolve(li, Site::KvCache).unwrap();
            assert_eq!((kv.target_bits, kv.group), (Some(4), 16));
        }
        // canonical form round-trips
        let s = p.to_string();
        let again = QuantPolicy::parse(&s).unwrap();
        assert_eq!(p.razor(), again.razor(), "canonical '{s}' must re-parse identically");
    }

    #[test]
    fn dsl_rejects_malformed_strings_with_clear_errors() {
        for (s, needle) in [
            ("", "empty"),
            ("w4a4", "PRESET:GROUP"),
            ("w4a4:", "malformed group"),
            ("w4a4:abc", "malformed group"),
            ("w4a4:0", "out of range"),
            ("w4a4:4096", "out of range"),
            ("w4a4kv8:16", "unknown kv suffix"),
            ("w4a4kv16:16", "unknown kv suffix"),
            ("w3a4:16", "unsupported weight width"),
            ("w4a5:16", "unsupported activation width"),
            ("bogus:16", "unknown policy preset"),
            ("w4a4:16;kv=8:16", "unsupported kv target"),
            ("w4a4:16;kv=4", "kv clause format"),
            ("w4a4:16;layers=x:w4a8", "bad layer index"),
            ("w4a4:16;layers=0:w4a8:nope", "malformed group"),
            ("w4a4:16;frobnicate", "unknown policy clause"),
            ("fp16;kv=4:16", "fp16 takes no clauses"),
            ("w4a4:16;w=down", "weight clause format"),
            ("w4a4:16;w=act:8", "not a weight site"),
            ("w4a4:16;w=down:5", "unsupported weight override width"),
            ("w4a4:16;w=down:8;w=down:4", "duplicate weight override"),
            ("w4a4:16;w=down:4:nope", "malformed group"),
        ] {
            let err = QuantPolicy::parse(s).unwrap_err().to_string();
            assert!(
                err.contains(needle),
                "'{s}' should fail mentioning '{needle}', got: {err}"
            );
        }
    }

    #[test]
    fn json_round_trips_razor_policies() {
        for s in [
            "fp16",
            "w4a4kv4:16",
            "w4a8:32",
            "w4a4:16;layers=0,3:w4a8;kv=4:16",
            "w4a4kv4:16;dynamic",
            "w4a4kv4:16;w=wo,down:8",
        ] {
            let p = QuantPolicy::parse(s).unwrap();
            let j = Json::parse(&p.to_json().to_string()).unwrap();
            let back = QuantPolicy::from_json(&j).unwrap();
            assert_eq!(p.razor(), back.razor(), "json round-trip for '{s}'");
            assert_eq!(p.to_string(), back.to_string());
        }
    }

    #[test]
    fn json_rejects_scheme_backends_and_bad_kinds() {
        let p = QuantPolicy::uniform(Box::new(crate::baselines::Fp16));
        let j = p.to_json();
        assert!(QuantPolicy::from_json(&j).unwrap_err().to_string().contains("scheme"));
        let bad = Json::from_pairs(vec![("kind", Json::from("nope"))]);
        assert!(QuantPolicy::from_json(&bad).is_err());
    }

    #[test]
    fn weight_clause_pins_sites_and_round_trips() {
        let p = QuantPolicy::parse("w4a4kv4:16;w=down,wo:8;w=wq:4:32").unwrap();
        // pinned sites resolve ahead of the class plan, at every layer
        assert_eq!(p.resolve(0, Site::Down).unwrap().target_bits, None);
        assert_eq!(p.resolve(5, Site::Wo).unwrap().target_bits, None);
        let wq = p.resolve(3, Site::Wq).unwrap();
        assert_eq!((wq.target_bits, wq.group), (Some(4), 32));
        // everything else keeps the base weight plan
        assert_eq!(p.resolve(0, Site::Gate).unwrap().target_bits, Some(4));
        // canonical form groups sites per token and re-parses identically
        let s = p.to_string();
        assert_eq!(s, "w4a4kv4:16;w=wq:4:32;w=wo,down:8");
        let again = QuantPolicy::parse(&s).unwrap();
        assert_eq!(p.razor(), again.razor());
        assert_eq!(again.to_string(), s, "canonical form is a fixed point");
        // escalated layers inherit the pinned sites
        let p = QuantPolicy::parse("w4a4:16;layers=0:w4a8;w=down:8").unwrap();
        assert_eq!(p.resolve(0, Site::Down).unwrap().target_bits, None);
        assert_eq!(p.resolve(1, Site::Down).unwrap().target_bits, None);
        let s = p.to_string();
        assert_eq!(QuantPolicy::parse(&s).unwrap().razor(), p.razor(), "'{s}' round-trips");
    }

    #[test]
    fn weight_site_overrides_resolve_before_the_class_plan() {
        let mut r = QuantPolicy::parse("w4a4kv4:16").unwrap().razor().unwrap().clone();
        r.base
            .weight_overrides
            .insert(Site::Down, SitePlan::new(8, None, 16));
        let p = QuantPolicy::from_razor(r).unwrap();
        assert_eq!(p.resolve(0, Site::Down).unwrap().target_bits, None);
        assert_eq!(p.resolve(0, Site::Gate).unwrap().target_bits, Some(4));
        // survives the JSON round-trip too
        let back = QuantPolicy::from_json(&p.to_json()).unwrap();
        assert_eq!(back.resolve(0, Site::Down).unwrap().target_bits, None);
    }

    #[test]
    fn dynamic_clause_suppresses_static_scales() {
        let p = QuantPolicy::parse("w4a4kv4:16;dynamic").unwrap();
        assert_eq!(p.effective_scale(0, Site::Act, Some(0.5)), None);
        assert_eq!(p.query_effective_scale(0, Some(0.5)), None);
        assert_eq!(p.resolve(0, Site::Act).unwrap().scaling, Scaling::Dynamic);
        // A dynamic KV plan cannot use the packed cache (it compresses
        // at static scales): the decode path falls back to FP, where
        // kv_transform honors the dynamic directive — eval and serve
        // stay consistent.
        assert!(p.kv_cache_specs(2, 64, 16).is_none());
        let p = QuantPolicy::parse("w4a4kv4:16").unwrap();
        assert_eq!(p.effective_scale(0, Site::Act, Some(0.5)), Some(0.5));
        assert!(p.kv_cache_specs(2, 64, 16).is_some());
    }

    #[test]
    fn check_layers_rejects_out_of_range_overrides() {
        let p = QuantPolicy::parse("w4a4:16;layers=0,11:w4a8").unwrap();
        assert!(p.check_layers(12).is_ok());
        let err = p.check_layers(11).unwrap_err().to_string();
        assert!(err.contains("overrides layer 11"), "{err}");
        assert!(err.contains("0..=10"), "{err}");
        // uniform scheme backends have no overrides
        let u = QuantPolicy::uniform(Box::new(crate::baselines::Fp16));
        assert!(u.check_layers(1).is_ok());
        // concrete boxed schemes convert through the blanket impl
        let c: QuantPolicy = Box::new(crate::baselines::QRazor::w4a4(16)).into();
        assert_eq!(c.name(), "QRazor-W4A4 g16");
    }

    #[test]
    fn kv_cache_specs_cover_every_layer_or_none() {
        let p = QuantPolicy::parse("w4a4kv4:16").unwrap();
        let specs = p.kv_cache_specs(3, 64, 16).unwrap();
        assert_eq!(specs.len(), 3);
        assert!(specs.iter().all(|s| *s == SdrSpec::new(8, 4, 16)));
        // group not dividing kv_dim → FP fallback
        assert!(p.kv_cache_specs(3, 60, 16).is_none());
        // no kv plan → FP fallback
        assert!(QuantPolicy::parse("w4a4:16").unwrap().kv_cache_specs(3, 64, 16).is_none());
        // kv=off drops the suffix plan
        let off = QuantPolicy::parse("w4a4kv4:16;kv=off").unwrap();
        assert!(!off.quantizes_kv());
        assert!(off.kv_cache_specs(2, 64, 16).is_none());
    }

    #[test]
    fn lm_head_resolves_against_the_base_plan() {
        let p = QuantPolicy::parse("w4a4:16;layers=0:w4a8").unwrap();
        // layer 0 escalated, but the head still reads the base
        assert_eq!(p.resolve(0, Site::LmHead).unwrap().target_bits, Some(4));
        assert_eq!(p.act_basis_bits(0, Site::LmHead), 16);
    }

    #[test]
    fn uniform_scheme_backend_delegates_to_the_hooks() {
        let p: QuantPolicy = (Box::new(crate::baselines::QRazor::w4a4kv4(16))
            as Box<dyn Scheme>)
            .into();
        assert_eq!(p.name(), "QRazor-W4A4KV4 g16");
        assert!(p.quantizes_kv());
        assert_eq!(p.sdr_query_spec(7), Some(SdrSpec::new(8, 4, 16)));
        assert!(p.resolve(0, Site::Act).is_none(), "scheme hooks are opaque");
        assert_eq!(p.act_basis_bits(0, Site::Act), 16);
        assert_eq!(p.kv_basis_bits(0), 8);
        let specs = p.kv_cache_specs(2, 64, 16).unwrap();
        assert_eq!(specs, vec![SdrSpec::new(8, 4, 16); 2]);
    }
}
