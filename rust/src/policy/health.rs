//! Scale-drift detection and the escalation advisor (the decision
//! layer over `crate::obs::health`).
//!
//! The obs layer produces raw [`ProbeSample`]s — per-site live/frozen
//! amax ratios and razoring error from sampled decode steps. This
//! module turns them into decisions:
//!
//! * [`DriftDetector`] — folds samples into a mergeable
//!   [`HealthStats`], maintaining a per-site EWMA of the drift ratio
//!   and latching a one-shot alarm the first time a site's EWMA
//!   crosses the configured threshold after a warm-up. A drift ratio
//!   near 1.0 means the frozen calibration still covers the live
//!   distribution; sustained ratios above ~1.5 mean stage-1 absmax is
//!   clipping mass the calibrator never saw.
//! * [`HealthReport`] — the operator view: worst-drifting sites, alarm
//!   flags, aggregate SNR, and (when the serving policy is
//!   razor-native) [`Advice`]: a concretely escalated [`QuantPolicy`]
//!   rendered as a ready-to-apply DSL string via the canonical
//!   `Display` form, so `--policy '<advice.dsl>'` is the whole fix.
//!
//! Advice stays inside the DSL-expressible subset: alarmed activation
//! sites escalate that layer's act plan A4 → A8 (the same move as
//! [`QuantPolicy::sensitivity_escalate`], but driven by live drift
//! instead of offline calibration error); alarmed `q`/`k`/`v` sites
//! drop KV razoring globally (`kv=off` — per-layer KV drops do not
//! round-trip the DSL, and a drifted KV site poisons every later
//! decode step). Sites already at their relaxed form become notes
//! instead of edits.

use std::collections::BTreeMap;
use std::fmt::Write as _;

use super::QuantPolicy;
use crate::obs::health::{HealthConfig, HealthStats, ProbeSample};
use crate::util::json::Json;

/// EWMA drift detector. Stateless — all evolving state lives in the
/// [`HealthStats`] it updates, which is what merges across shards.
#[derive(Clone, Debug, Default)]
pub struct DriftDetector {
    pub cfg: HealthConfig,
}

impl DriftDetector {
    pub fn new(cfg: HealthConfig) -> DriftDetector {
        DriftDetector { cfg }
    }

    /// Fold one probed step's sample for a site into `stats`. Returns
    /// `true` exactly once per site: on the sample where the EWMA
    /// first crosses `alarm_ratio` with the warm-up
    /// (`min_samples`) satisfied. The alarm latches — a site that
    /// drifts back under the threshold stays flagged until reset,
    /// because the tokens decoded while it was out of range are
    /// already suspect.
    pub fn observe(&self, stats: &mut HealthStats, s: &ProbeSample) -> bool {
        stats.probe_samples += s.samples;
        stats.drift.record(s.drift);
        if let Some(snr) = s.snr_db() {
            stats.snr_db.record(snr);
        }
        let site = stats.sites.entry(s.site.clone()).or_default();
        site.samples += 1;
        site.last = s.drift;
        site.peak = site.peak.max(s.drift_peak);
        site.mse_sum += s.mse;
        site.ref_sum += s.ref_pow;
        site.ewma = if site.samples == 1 {
            s.drift
        } else {
            self.cfg.ewma_alpha * s.drift + (1.0 - self.cfg.ewma_alpha) * site.ewma
        };
        if !site.alarmed && site.samples >= self.cfg.min_samples && site.ewma > self.cfg.alarm_ratio
        {
            site.alarmed = true;
            stats.drift_alarms += 1;
            return true;
        }
        false
    }

    /// Feed a bare drift ratio for `site` (property tests and the
    /// bench harness; no razoring-error component).
    pub fn observe_ratio(&self, stats: &mut HealthStats, site: &str, drift: f64) -> bool {
        self.observe(
            stats,
            &ProbeSample {
                site: site.to_string(),
                drift,
                drift_peak: drift,
                samples: 1,
                mse: 0.0,
                ref_pow: 0.0,
            },
        )
    }
}

/// One row of the worst-sites table.
#[derive(Clone, Debug, PartialEq)]
pub struct SiteReport {
    pub site: String,
    pub ewma: f64,
    pub last: f64,
    pub peak: f64,
    pub samples: u64,
    pub snr_db: f64,
    pub alarmed: bool,
}

/// The operator-facing digest of a [`HealthStats`]: worst-N drifting
/// sites, alarm inventory, and concrete escalation advice.
#[derive(Clone, Debug)]
pub struct HealthReport {
    /// Sites ordered by drift EWMA, worst first, truncated to the
    /// requested table size.
    pub worst: Vec<SiteReport>,
    /// Every site whose alarm has latched.
    pub alarmed_sites: Vec<String>,
    pub probe_steps: u64,
    pub drift_alarms: u64,
    /// Escalation advice; `None` when nothing alarmed or nothing is
    /// DSL-expressible (uniform scheme backends).
    pub advice: Option<Advice>,
}

impl HealthReport {
    /// Digest `stats` against the policy that produced it. `worst_n`
    /// bounds the table, not the alarm inventory.
    pub fn from_stats(stats: &HealthStats, policy: &QuantPolicy, worst_n: usize) -> HealthReport {
        let mut rows: Vec<SiteReport> = stats
            .sites
            .iter()
            .map(|(site, s)| SiteReport {
                site: site.clone(),
                ewma: s.ewma,
                last: s.last,
                peak: s.peak,
                samples: s.samples,
                snr_db: s.snr_db(),
                alarmed: s.alarmed,
            })
            .collect();
        rows.sort_by(|a, b| {
            b.ewma
                .partial_cmp(&a.ewma)
                .unwrap_or(std::cmp::Ordering::Equal)
                .then_with(|| a.site.cmp(&b.site))
        });
        rows.truncate(worst_n);
        let alarmed_sites: Vec<String> = stats
            .sites
            .iter()
            .filter(|(_, s)| s.alarmed)
            .map(|(site, _)| site.clone())
            .collect();
        let advice = advise(policy, &alarmed_sites);
        HealthReport {
            worst: rows,
            alarmed_sites,
            probe_steps: stats.probe_steps,
            drift_alarms: stats.drift_alarms,
            advice,
        }
    }

    /// Plain-text table for the CLI (`serve --health`, `quantize`).
    pub fn render_table(&self) -> String {
        let mut out = String::new();
        let _ = writeln!(
            out,
            "numeric health: {} probe steps, {} drift alarms",
            self.probe_steps, self.drift_alarms
        );
        if self.worst.is_empty() {
            out.push_str("  (no probed sites — health probes off or no decode steps)\n");
            return out;
        }
        let _ = writeln!(
            out,
            "  {:<16} {:>8} {:>8} {:>8} {:>8} {:>8}  {}",
            "site", "ewma", "last", "peak", "samples", "snr_db", "alarm"
        );
        for r in &self.worst {
            let _ = writeln!(
                out,
                "  {:<16} {:>8.3} {:>8.3} {:>8.3} {:>8} {:>8.1}  {}",
                r.site,
                r.ewma,
                r.last,
                r.peak,
                r.samples,
                r.snr_db,
                if r.alarmed { "ALARM" } else { "-" }
            );
        }
        match &self.advice {
            Some(a) => {
                let _ = writeln!(out, "  advisor: --policy '{}'", a.dsl);
                for n in &a.notes {
                    let _ = writeln!(out, "  advisor: {n}");
                }
            }
            None if !self.alarmed_sites.is_empty() => {
                out.push_str("  advisor: no DSL-expressible escalation for this policy\n");
            }
            None => {}
        }
        out
    }

    pub fn to_json(&self) -> Json {
        let worst: Vec<Json> = self
            .worst
            .iter()
            .map(|r| {
                Json::from_pairs(vec![
                    ("site", Json::from(r.site.as_str())),
                    ("ewma", Json::from(r.ewma)),
                    ("last", Json::from(r.last)),
                    ("peak", Json::from(r.peak)),
                    ("samples", Json::from(r.samples as f64)),
                    ("snr_db", Json::from(r.snr_db)),
                    ("alarmed", Json::from(r.alarmed)),
                ])
            })
            .collect();
        let alarmed: Vec<Json> =
            self.alarmed_sites.iter().map(|s| Json::from(s.as_str())).collect();
        Json::from_pairs(vec![
            ("probe_steps", Json::from(self.probe_steps as f64)),
            ("drift_alarms", Json::from(self.drift_alarms as f64)),
            ("worst", Json::Arr(worst)),
            ("alarmed_sites", Json::Arr(alarmed)),
            ("advice", self.advice.as_ref().map(|a| a.to_json()).unwrap_or(Json::Null)),
        ])
    }
}

/// A concrete, ready-to-apply escalation.
#[derive(Clone, Debug)]
pub struct Advice {
    /// The escalated policy itself.
    pub escalated: QuantPolicy,
    /// Canonical DSL for [`Advice::escalated`] — paste into
    /// `--policy` to apply.
    pub dsl: String,
    /// Layers whose act plan was escalated A4 → A8.
    pub act_layers: Vec<usize>,
    /// Whether KV razoring was dropped (`kv=off`) for alarmed
    /// q/k/v sites.
    pub kv_dropped: bool,
    /// Alarmed sites the advisor could not (or did not need to) edit.
    pub notes: Vec<String>,
}

impl Advice {
    pub fn to_json(&self) -> Json {
        let layers: Vec<Json> =
            self.act_layers.iter().map(|&l| Json::from(l as f64)).collect();
        let notes: Vec<Json> = self.notes.iter().map(|n| Json::from(n.as_str())).collect();
        Json::from_pairs(vec![
            ("dsl", Json::from(self.dsl.as_str())),
            ("act_layers", Json::Arr(layers)),
            ("kv_dropped", Json::from(self.kv_dropped)),
            ("notes", Json::Arr(notes)),
        ])
    }
}

/// Classify a calibration-site name into the escalation it wants.
enum SiteClass {
    /// `l{li}.{attn_in,attn_out,ffn_in,ffn_down_in}` — the layer's
    /// activation plan.
    Act(usize),
    /// `l{li}.{q,k,v}` — the attention operand / KV-cache plans.
    Kv(usize),
    /// `lm_head_in` — governed by the base act plan.
    LmHead,
    Unknown,
}

fn classify(site: &str) -> SiteClass {
    if site == "lm_head_in" {
        return SiteClass::LmHead;
    }
    let Some(rest) = site.strip_prefix('l') else {
        return SiteClass::Unknown;
    };
    let Some((li, kind)) = rest.split_once('.') else {
        return SiteClass::Unknown;
    };
    let Ok(li) = li.parse::<usize>() else {
        return SiteClass::Unknown;
    };
    match kind {
        "attn_in" | "attn_out" | "ffn_in" | "ffn_down_in" => SiteClass::Act(li),
        "q" | "k" | "v" => SiteClass::Kv(li),
        _ => SiteClass::Unknown,
    }
}

/// Map alarmed sites to a DSL-expressible escalation of `policy`.
/// Returns `None` when there is nothing to escalate: no alarms, a
/// uniform scheme backend (opaque hooks — nothing to rewrite), or
/// every alarmed site already at its relaxed form.
pub fn advise(policy: &QuantPolicy, alarmed_sites: &[String]) -> Option<Advice> {
    if alarmed_sites.is_empty() {
        return None;
    }
    let r = policy.razor()?;
    let mut act_layers: BTreeMap<usize, bool> = BTreeMap::new();
    let mut kv_layers: Vec<usize> = Vec::new();
    let mut notes = Vec::new();
    for site in alarmed_sites {
        match classify(site) {
            SiteClass::Act(li) => {
                act_layers.entry(li).or_insert(false);
            }
            SiteClass::Kv(li) => kv_layers.push(li),
            SiteClass::LmHead => notes.push(
                "lm_head_in drifted: the head reads the base act plan; consider a \
                 full A8 base policy"
                    .to_string(),
            ),
            SiteClass::Unknown => notes.push(format!("unrecognized alarmed site '{site}'")),
        }
    }
    let mut out = r.clone();
    let mut escalated_layers = Vec::new();
    for (&li, _) in &act_layers {
        let mut plan = out.layer(li).clone();
        match plan.act.as_mut() {
            Some(a) if a.target_bits == Some(4) => {
                a.target_bits = Some(8);
                out.overrides.insert(li, plan);
                escalated_layers.push(li);
            }
            Some(_) => notes.push(format!("layer {li} act already above A4; no edit")),
            None => notes.push(format!("layer {li} act is FP; no edit")),
        }
    }
    let mut kv_dropped = false;
    if !kv_layers.is_empty() {
        // Per-layer KV drops do not round-trip the DSL, so a drifted
        // q/k/v site relaxes KV razoring globally.
        let had_kv = out.base.kv.is_some()
            || out.overrides.values().any(|p| p.kv.is_some() || p.query.is_some());
        if had_kv {
            out.base.kv = None;
            out.base.query = None;
            for plan in out.overrides.values_mut() {
                plan.kv = None;
                plan.query = None;
            }
            kv_dropped = true;
        } else {
            kv_layers.sort_unstable();
            kv_layers.dedup();
            notes.push(format!(
                "kv/query sites drifted on layers {kv_layers:?} but KV is already FP"
            ));
        }
    }
    if escalated_layers.is_empty() && !kv_dropped {
        return None;
    }
    let escalated = QuantPolicy::from_razor(out).ok()?;
    let dsl = escalated.to_string();
    Some(Advice { escalated, dsl, act_layers: escalated_layers, kv_dropped, notes })
}

#[cfg(test)]
mod tests {
    use super::*;

    fn detector(alarm: f64, alpha: f64, min: u64) -> DriftDetector {
        DriftDetector::new(HealthConfig {
            sample_every_n_steps: 1,
            alarm_ratio: alarm,
            ewma_alpha: alpha,
            min_samples: min,
        })
    }

    #[test]
    fn stationary_drift_never_alarms() {
        let d = detector(1.5, 0.3, 2);
        let mut stats = HealthStats::default();
        for _ in 0..200 {
            assert!(!d.observe_ratio(&mut stats, "l0.attn_in", 1.02));
        }
        assert_eq!(stats.drift_alarms, 0);
        assert!(!stats.sites["l0.attn_in"].alarmed);
    }

    #[test]
    fn ramp_alarms_exactly_once() {
        let d = detector(1.5, 0.3, 2);
        let mut stats = HealthStats::default();
        let mut fires = 0;
        for i in 0..50 {
            let drift = 1.0 + i as f64 * 0.05; // monotone ramp past 1.5
            if d.observe_ratio(&mut stats, "l1.ffn_in", drift) {
                fires += 1;
            }
        }
        assert_eq!(fires, 1, "alarm must latch, not refire");
        assert_eq!(stats.drift_alarms, 1);
        assert!(stats.sites["l1.ffn_in"].alarmed);
    }

    #[test]
    fn warmup_suppresses_first_sample_spike() {
        let d = detector(1.5, 0.3, 3);
        let mut stats = HealthStats::default();
        assert!(!d.observe_ratio(&mut stats, "s", 9.0));
        assert!(!d.observe_ratio(&mut stats, "s", 9.0));
        assert!(d.observe_ratio(&mut stats, "s", 9.0));
    }

    #[test]
    fn advisor_escalates_act_layers_and_round_trips() {
        let p = QuantPolicy::parse("w4a4kv4:16").unwrap();
        let alarmed = vec!["l1.ffn_in".to_string(), "l1.attn_out".to_string()];
        let a = advise(&p, &alarmed).expect("escalation expected");
        assert_eq!(a.act_layers, vec![1]);
        assert!(!a.kv_dropped);
        assert_eq!(a.dsl, "w4a4kv4:16;layers=1:w4a8");
        let re = QuantPolicy::parse(&a.dsl).unwrap();
        assert_eq!(re.razor(), a.escalated.razor(), "advice DSL must round-trip");
    }

    #[test]
    fn advisor_drops_kv_on_kv_site_alarms() {
        let p = QuantPolicy::parse("w4a4kv4:16").unwrap();
        let a = advise(&p, &["l2.k".to_string()]).expect("kv drop expected");
        assert!(a.kv_dropped);
        assert!(a.escalated.razor().unwrap().base.kv.is_none());
        assert_eq!(a.dsl, "w4a4:16");
    }

    #[test]
    fn advisor_none_when_nothing_expressible() {
        let p = QuantPolicy::uniform(Box::new(crate::baselines::Fp16));
        assert!(advise(&p, &["l0.attn_in".to_string()]).is_none());
        let razor = QuantPolicy::parse("w4a8:16").unwrap();
        // A8 already: act sites produce notes, not edits → None.
        assert!(advise(&razor, &["l0.attn_in".to_string()]).is_none());
        assert!(advise(&razor, &[]).is_none());
    }

    #[test]
    fn report_orders_worst_first_and_carries_advice() {
        let d = detector(1.5, 1.0, 1);
        let mut stats = HealthStats::default();
        d.observe_ratio(&mut stats, "l0.attn_in", 1.1);
        d.observe_ratio(&mut stats, "l1.ffn_in", 2.5);
        d.observe_ratio(&mut stats, "l2.q", 1.3);
        let p = QuantPolicy::parse("w4a4kv4:16").unwrap();
        let rep = HealthReport::from_stats(&stats, &p, 2);
        assert_eq!(rep.worst.len(), 2);
        assert_eq!(rep.worst[0].site, "l1.ffn_in");
        assert_eq!(rep.alarmed_sites, vec!["l1.ffn_in".to_string()]);
        let advice = rep.advice.expect("alarmed act site must yield advice");
        assert_eq!(advice.act_layers, vec![1]);
        let table = rep.render_table();
        assert!(table.contains("l1.ffn_in"));
        assert!(table.contains("ALARM"));
        assert!(table.contains("--policy"));
    }
}
