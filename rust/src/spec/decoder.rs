//! The speculative draft→verify→accept loop and its model contract.
//!
//! See the module doc of [`crate::spec`] for the algorithm and its
//! invariants; this file holds the mechanics: [`SpecLm`] (what the loop
//! needs from a model + decode state), [`QuantLm`] (the real
//! [`QuantModel`] implementation the engine uses), [`SpecDecoder`]
//! (the loop itself) and [`SpecStats`] (per-request acceptance
//! accounting).

use std::sync::Arc;

use crate::model::quantized::{DecodeCache, QuantModel};
use crate::tensor::argmax;

/// What the speculative loop needs from a language model plus its
/// incremental decode state. Implementations own their KV cache; the
/// loop only ever observes row counts, feeds tokens, and rolls back.
pub trait SpecLm {
    /// Rows currently held by the decode cache (tokens fed so far).
    fn cached_tokens(&self) -> usize;
    /// Feed one token at absolute position `pos`, appending its KV
    /// row; returns next-token logits.
    fn forward_token(&mut self, token: u32, pos: usize) -> Vec<f32>;
    /// Feed `tokens` at positions `start_pos..`, appending every row;
    /// returns one logits row per fed token. Must equal feeding the
    /// tokens one at a time (the verify-pass identity).
    fn forward_chunk(&mut self, tokens: &[u32], start_pos: usize) -> Vec<Vec<f32>>;
    /// Drop cached rows past the first `tokens` (speculative rollback).
    fn truncate(&mut self, tokens: usize);
}

/// A [`QuantModel`] plus its [`DecodeCache`]: the engine-side
/// [`SpecLm`]. The draft side wraps the packed W4A4 model, the target
/// side the W4A8 basis model — both built from the same weights and
/// calibration.
pub struct QuantLm {
    pub model: Arc<QuantModel>,
    cache: DecodeCache,
}

impl QuantLm {
    /// Fresh decode state for `model` (SDR-compressed cache when the
    /// scheme quantizes KV).
    pub fn new(model: Arc<QuantModel>, kv_group: usize) -> QuantLm {
        let cache = model.new_cache(kv_group);
        QuantLm { model, cache }
    }

    /// Rewrap a cache the caller parked elsewhere (the engine's pools).
    pub fn from_parts(model: Arc<QuantModel>, cache: DecodeCache) -> QuantLm {
        QuantLm { model, cache }
    }

    /// Hand the cache back to its pool.
    pub fn into_cache(self) -> DecodeCache {
        self.cache
    }

    /// Inspect the decode state (tests and byte accounting).
    pub fn cache(&self) -> &DecodeCache {
        &self.cache
    }
}

impl SpecLm for QuantLm {
    fn cached_tokens(&self) -> usize {
        self.cache.tokens()
    }

    fn forward_token(&mut self, token: u32, pos: usize) -> Vec<f32> {
        self.model.forward_token(token, pos, &mut self.cache)
    }

    fn forward_chunk(&mut self, tokens: &[u32], start_pos: usize) -> Vec<Vec<f32>> {
        let logits = self.model.forward_chunk(tokens, start_pos, &mut self.cache);
        (0..tokens.len()).map(|i| logits.row(i).to_vec()).collect()
    }

    fn truncate(&mut self, tokens: usize) {
        self.cache.truncate(tokens)
    }
}

/// Per-request speculative accounting, merged into the serving metrics.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct SpecStats {
    /// Draft→verify→accept rounds taken.
    pub steps: u64,
    /// Draft tokens proposed.
    pub drafted: u64,
    /// Draft tokens the basis verify pass accepted.
    pub accepted: u64,
    /// Draft tokens rejected and rolled back (`drafted - accepted`).
    pub rejected: u64,
}

impl SpecStats {
    /// Fraction of drafted tokens accepted (0 when nothing drafted).
    pub fn acceptance(&self) -> f64 {
        if self.drafted == 0 {
            0.0
        } else {
            self.accepted as f64 / self.drafted as f64
        }
    }

    pub fn merge(&mut self, other: &SpecStats) {
        self.steps += other.steps;
        self.drafted += other.drafted;
        self.accepted += other.accepted;
        self.rejected += other.rejected;
    }
}

/// The speculative loop: `k` cheap draft tokens per round, one batched
/// basis-precision verify pass, longest greedy-matching prefix kept.
/// `k = 0` degenerates to plain target-only decode (one verify row,
/// zero drafts).
#[derive(Clone, Copy, Debug)]
pub struct SpecDecoder {
    /// Lookahead length per round.
    pub k: usize,
}

impl SpecDecoder {
    pub fn new(k: usize) -> SpecDecoder {
        SpecDecoder { k }
    }

    /// One draft→verify→accept round. `seq` is every committed token
    /// (prompt + generated), its last element the next token to feed;
    /// the target cache must hold exactly `seq.len() - 1` rows, the
    /// draft cache at most that many (it is caught up here). Returns
    /// the newly committed tokens — between 1 and `k + 1` of them —
    /// and leaves both caches truncated to the committed prefix.
    pub fn step(
        &self,
        seq: &[u32],
        draft: &mut impl SpecLm,
        target: &mut impl SpecLm,
        stats: &mut SpecStats,
    ) -> Vec<u32> {
        assert!(!seq.is_empty(), "speculative step needs at least one token");
        let p = seq.len() - 1;
        debug_assert_eq!(target.cached_tokens(), p, "verify cache out of sync");
        let next = *seq.last().unwrap();
        stats.steps += 1;

        // ---- draft phase: k greedy proposals on the razored path
        let mut chunk = Vec::with_capacity(self.k + 1);
        chunk.push(next);
        if self.k > 0 {
            let hot = crate::obs::HotSpan::begin();
            // Catch the draft cache up (it lags one row after a fully
            // accepted round, arbitrarily after a sampling fallback).
            let d = draft.cached_tokens();
            debug_assert!(d <= p, "draft cache ahead of the committed prefix");
            if d < p {
                let _ = draft.forward_chunk(&seq[d..p], d);
            }
            let mut tok = next;
            for i in 0..self.k {
                let logits = draft.forward_token(tok, p + i);
                tok = argmax(&logits) as u32;
                chunk.push(tok);
            }
            stats.drafted += self.k as u64;
            hot.finish(crate::obs::HotStage::SpecDraft);
        }

        // ---- verify: one batched chunk at the basis precision
        let hot = crate::obs::HotSpan::begin();
        let rows = target.forward_chunk(&chunk, p);
        hot.finish(crate::obs::HotStage::SpecVerify);
        debug_assert_eq!(rows.len(), chunk.len());
        let choices: Vec<u32> = rows.iter().map(|r| argmax(r) as u32).collect();

        // ---- accept the longest greedy-matching prefix + the bonus
        // or correction token the verify pass already paid for
        let mut a = 0usize;
        while a < self.k && chunk[a + 1] == choices[a] {
            a += 1;
        }
        let mut out: Vec<u32> = chunk[1..=a].to_vec();
        out.push(choices[a]);
        stats.accepted += a as u64;
        stats.rejected += (self.k - a) as u64;

        // ---- rollback: rejected rows leave both caches byte-exactly
        let committed = p + a + 1;
        target.truncate(committed);
        if self.k > 0 {
            let keep = draft.cached_tokens().min(committed);
            draft.truncate(keep);
        }
        out
    }

    /// Greedy-decode `max_new` tokens speculatively, committing rounds
    /// until the budget is reached (the tail round is trimmed). `seq`
    /// is the full prompt; returns the generated tokens. Used by the
    /// property tests and the bench; the serving engine drives
    /// [`SpecDecoder::step`] itself so rounds interleave with
    /// continuous batching.
    pub fn generate(
        &self,
        prompt: &[u32],
        draft: &mut impl SpecLm,
        target: &mut impl SpecLm,
        max_new: usize,
        stats: &mut SpecStats,
    ) -> Vec<u32> {
        assert!(!prompt.is_empty(), "empty prompt");
        // prefill the verify cache (all but the last prompt token)
        if prompt.len() > 1 {
            let _ = target.forward_chunk(&prompt[..prompt.len() - 1], 0);
        }
        let mut seq = prompt.to_vec();
        let mut out = Vec::with_capacity(max_new);
        while out.len() < max_new {
            let new = self.step(&seq, draft, target, stats);
            for tok in new {
                if out.len() == max_new {
                    // trim the over-committed tail: the caches keep the
                    // extra rows, but the stream stops at the budget
                    break;
                }
                out.push(tok);
                seq.push(tok);
            }
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::baselines::QRazor;
    use crate::config::ModelConfig;
    use crate::model::quantized::{calibrate, QuantModel};
    use crate::model::ModelWeights;
    use crate::util::rng::Rng;

    fn models(seed: u64) -> (Arc<QuantModel>, Arc<QuantModel>) {
        let cfg = ModelConfig::preset("nano").unwrap();
        let w = ModelWeights::init_random(&cfg, seed);
        let mut rng = Rng::new(seed + 1);
        let seqs: Vec<Vec<u32>> = (0..2)
            .map(|_| (0..16).map(|_| rng.below(cfg.vocab as u64) as u32).collect())
            .collect();
        let cal = calibrate(&w, &seqs);
        let target = Arc::new(QuantModel::build(&w, Box::new(QRazor::w4a8kv4(16)), &cal));
        let draft = Arc::new(QuantModel::build(&w, Box::new(QRazor::w4a4kv4(16)), &cal));
        (target, draft)
    }

    /// Target-only greedy decode through the plain token loop — the
    /// stream every speculative configuration must reproduce.
    fn greedy_baseline(model: &Arc<QuantModel>, prompt: &[u32], max_new: usize) -> Vec<u32> {
        let mut cache = model.new_cache(16);
        for (pos, &tok) in prompt[..prompt.len() - 1].iter().enumerate() {
            model.forward_token(tok, pos, &mut cache);
        }
        let mut out = Vec::new();
        let mut tok = *prompt.last().unwrap();
        let mut pos = prompt.len() - 1;
        while out.len() < max_new {
            let logits = model.forward_token(tok, pos, &mut cache);
            tok = argmax(&logits) as u32;
            pos += 1;
            out.push(tok);
        }
        out
    }

    /// A deliberately wrong drafter: forwards the target model but
    /// argmin-flips the logits, so its greedy proposal disagrees with
    /// the target's choice at (essentially) every position — the
    /// all-rejected edge case.
    struct AntiLm(QuantLm);

    impl SpecLm for AntiLm {
        fn cached_tokens(&self) -> usize {
            self.0.cached_tokens()
        }
        fn forward_token(&mut self, token: u32, pos: usize) -> Vec<f32> {
            self.0.forward_token(token, pos).iter().map(|&v| -v).collect()
        }
        fn forward_chunk(&mut self, tokens: &[u32], start_pos: usize) -> Vec<Vec<f32>> {
            self.0
                .forward_chunk(tokens, start_pos)
                .into_iter()
                .map(|r| r.iter().map(|&v| -v).collect())
                .collect()
        }
        fn truncate(&mut self, tokens: usize) {
            self.0.truncate(tokens)
        }
    }

    #[test]
    fn speculative_greedy_equals_target_only_greedy() {
        // The acceptance-criterion identity on a fixed case, for every
        // lookahead depth including k = 0.
        let (target, draft) = models(41);
        let prompt = vec![3u32, 7, 1, 9, 4];
        let want = greedy_baseline(&target, &prompt, 12);
        for k in 0..=4usize {
            let mut t = QuantLm::new(Arc::clone(&target), 16);
            let mut d = QuantLm::new(Arc::clone(&draft), 16);
            let mut stats = SpecStats::default();
            let got = SpecDecoder::new(k).generate(&prompt, &mut d, &mut t, 12, &mut stats);
            assert_eq!(got, want, "k={k} diverged from target-only greedy");
            assert_eq!(stats.drafted, stats.accepted + stats.rejected, "k={k}");
            if k == 0 {
                assert_eq!(stats.drafted, 0);
                assert_eq!(stats.steps, 12, "k=0 is one token per round");
            } else {
                assert!(stats.steps <= 12, "k={k}: speculation must not add rounds");
            }
        }
    }

    #[test]
    fn prop_speculative_greedy_equals_target_only_greedy() {
        // Random models, prompts, and k: the speculative stream is
        // always token-identical to target-only greedy decode.
        use crate::util::quickcheck::{check, Config, IntRange};
        let (target, draft) = models(43);
        let vocab = target.config.vocab as u64;
        let cfg = Config { cases: 8, ..Default::default() };
        check("spec≡greedy", cfg, &IntRange { lo: 1, hi: 1_000_000 }, |&seed| {
            let mut rng = Rng::new(seed as u64);
            let len = 2 + rng.index(8);
            let prompt: Vec<u32> = (0..len).map(|_| rng.below(vocab) as u32).collect();
            let k = rng.index(5); // 0..=4
            let max_new = 3 + rng.index(10);
            let want = greedy_baseline(&target, &prompt, max_new);
            let mut t = QuantLm::new(Arc::clone(&target), 16);
            let mut d = QuantLm::new(Arc::clone(&draft), 16);
            let mut stats = SpecStats::default();
            let got =
                SpecDecoder::new(k).generate(&prompt, &mut d, &mut t, max_new, &mut stats);
            got == want && stats.drafted == stats.accepted + stats.rejected
        });
    }

    #[test]
    fn all_rejected_drafts_still_produce_the_target_stream() {
        // Adversarial draft: every proposal disagrees, every round
        // rolls all k drafts back — output must still be the exact
        // target stream, one committed token per round.
        let (target, _) = models(47);
        let prompt = vec![5u32, 2, 8];
        let want = greedy_baseline(&target, &prompt, 8);
        let mut t = QuantLm::new(Arc::clone(&target), 16);
        let mut d = AntiLm(QuantLm::new(Arc::clone(&target), 16));
        let mut stats = SpecStats::default();
        let got = SpecDecoder::new(3).generate(&prompt, &mut d, &mut t, 8, &mut stats);
        assert_eq!(got, want);
        assert_eq!(stats.accepted, 0, "anti-draft must never be accepted");
        assert_eq!(stats.rejected, stats.drafted);
        assert_eq!(stats.steps, 8, "one committed token per all-rejected round");
        assert!((stats.acceptance() - 0.0).abs() < 1e-12);
    }

    #[test]
    fn self_drafting_accepts_everything() {
        // Draft == target: the verify pass agrees with every proposal
        // (the chunk ≡ sequential identity), so each round commits
        // k + 1 tokens and acceptance is exactly 1.
        let (target, _) = models(53);
        let prompt = vec![1u32, 6, 2, 9];
        let want = greedy_baseline(&target, &prompt, 12);
        let mut t = QuantLm::new(Arc::clone(&target), 16);
        let mut d = QuantLm::new(Arc::clone(&target), 16);
        let mut stats = SpecStats::default();
        let got = SpecDecoder::new(3).generate(&prompt, &mut d, &mut t, 12, &mut stats);
        assert_eq!(got, want);
        assert_eq!(stats.rejected, 0);
        assert!((stats.acceptance() - 1.0).abs() < 1e-12);
        assert_eq!(stats.steps, 3, "12 tokens in rounds of k+1 = 4");
    }

    #[test]
    fn verify_cache_stays_byte_exact_across_rounds() {
        // After every round the verify cache must hold exactly the
        // committed rows — compare against a fresh cache fed the same
        // prefix (speculate→reject→truncate leaves no residue).
        let (target, draft) = models(59);
        let prompt = vec![4u32, 4, 7];
        let mut t = QuantLm::new(Arc::clone(&target), 16);
        let mut d = QuantLm::new(Arc::clone(&draft), 16);
        let mut stats = SpecStats::default();
        let _ = t.forward_chunk(&prompt[..2], 0);
        let mut seq = prompt.clone();
        let dec = SpecDecoder::new(2);
        for _ in 0..4 {
            let new = dec.step(&seq, &mut d, &mut t, &mut stats);
            seq.extend(new);
            assert_eq!(t.cached_tokens(), seq.len() - 1, "verify rows != committed prefix");
            // a cache that only ever saw the committed prefix agrees
            // byte for byte
            let mut fresh = QuantLm::new(Arc::clone(&target), 16);
            let _ = fresh.forward_chunk(&seq[..seq.len() - 1], 0);
            assert_eq!(fresh.cache().bytes(), t.cache().bytes(), "byte accounting drifted");
        }
        assert!(stats.steps == 4);
    }
}
