//! Self-speculative decoding: **draft on the razored 4-bit form,
//! verify on the 8-bit basis** — the serving subsystem that turns
//! QRazor's two-stage design into lookahead throughput.
//!
//! QRazor derives every tensor at two fidelities from the *same* data:
//! the stage-1 absmax basis (W8/A16/KV8 integers, served as W4A8) and
//! the stage-2 SDR razored form (packed W4A4KV4). That is exactly the
//! draft/target pair speculative decoding needs — no second model, no
//! training: the cheap packed path proposes `k` lookahead tokens, one
//! batched pass at the basis precision scores all `k + 1` positions,
//! and the longest greedy-matching prefix is kept.
//!
//! Since the per-site policy redesign the fidelity split is expressed
//! as **two named [`crate::policy::QuantPolicy`]s** carried by
//! [`crate::config::ServeConfig`] (`policy` = verify, `draft_policy`
//! = draft, both in the policy DSL): the CLI builds the pair from the
//! one serve manifest, and either side may itself be a mixed
//! per-layer policy (e.g. a sensitivity-escalated W4A4/W4A8 verify
//! over a uniform W4A4 draft).
//!
//! # Algorithm (one [`SpecDecoder::step`])
//!
//! With `seq` the committed tokens (prompt + generated; the last one is
//! the next to feed) and `P = seq.len() - 1` rows in the verify cache:
//!
//! 1. **Draft** — feed `seq.last()` then each proposal through the
//!    draft model's [`SpecLm::forward_token`], producing `d₁ … d_k`
//!    by greedy argmax. (First, the draft cache is caught up to `P`
//!    rows if it lags — see rollback below.)
//! 2. **Verify** — one [`SpecLm::forward_chunk`] of
//!    `[seq.last(), d₁ … d_k]` on the target model: `k + 1` logit rows
//!    in a single batched pass (batched linears + multi-query packed
//!    attention), bit-identical to feeding the tokens one at a time.
//!    Row `i`'s argmax `g_i` is what target-only greedy decode would
//!    have emitted after `seq ++ d₁..dᵢ`.
//! 3. **Accept** — keep the longest prefix with `d_{i+1} == g_i`
//!    (`a` tokens), then commit `g_a` as well: the correction when
//!    `a < k`, the bonus token when every draft was accepted. Each
//!    step therefore commits between 1 and `k + 1` tokens.
//! 4. **Rollback** — both caches are truncated to the committed
//!    prefix (`P + a + 1` rows); rejected rows leave the packed pools
//!    byte-exactly ([`crate::model::quantized::DecodeCache::truncate`]).
//!    After a fully-accepted step the draft cache legitimately lags
//!    one row (it never fed `d_k`); the next step's catch-up feeds it.
//!
//! # Invariants
//!
//! * **Greedy identity**: the committed stream is token-for-token
//!   identical to target-only greedy decode, for every `k` (including
//!   `k = 0`, which *is* target-only decode) and every draft — even an
//!   adversarial one. Property-tested in [`decoder`].
//! * **Cache exactness**: after every step the verify cache holds
//!   exactly the committed rows; byte accounting survives any number
//!   of speculate→reject→truncate cycles.
//! * Acceptance, rejection, and step counts are reported per request
//!   through [`SpecStats`] and surface in the serving metrics.
//!
//! [`decoder::SpecLm`] abstracts the two models so the engine's
//! [`decoder::QuantLm`] (an `Arc<QuantModel>` + its `DecodeCache`) and
//! the bench's synthetic cost models drive the same loop. The serving
//! integration lives in [`crate::coordinator::scheduler`] (`spec_k` in
//! `ServeConfig`, draft pool, per-step stats) and fans out across
//! [`crate::cluster`] shards unchanged.

pub mod decoder;

pub use decoder::{QuantLm, SpecDecoder, SpecLm, SpecStats};
