//! Minimal dense tensor library.
//!
//! The reproduction needs a tensor substrate for three distinct uses:
//! float reference math (model inference, baselines), integer-domain
//! QRazor data (i32 lattices), and views/slices for per-channel and
//! per-group traversals. This module provides a row-major `Tensor<T>`
//! with shape/stride bookkeeping and the handful of ops the system
//! needs — not a general autograd framework (training happens in L2/JAX).

mod ops;

pub use ops::*;

/// Dense row-major tensor.
#[derive(Clone, Debug, PartialEq)]
pub struct Tensor<T> {
    shape: Vec<usize>,
    data: Vec<T>,
}

pub type TensorF = Tensor<f32>;
pub type TensorI = Tensor<i32>;

impl<T: Copy + Default> Tensor<T> {
    /// Zero-initialized tensor of the given shape.
    pub fn zeros(shape: &[usize]) -> Self {
        let n = shape.iter().product();
        Tensor { shape: shape.to_vec(), data: vec![T::default(); n] }
    }

    /// Build from existing data; length must match the shape product.
    pub fn from_vec(shape: &[usize], data: Vec<T>) -> Self {
        assert_eq!(
            shape.iter().product::<usize>(),
            data.len(),
            "shape {:?} incompatible with data len {}",
            shape,
            data.len()
        );
        Tensor { shape: shape.to_vec(), data }
    }

    pub fn full(shape: &[usize], v: T) -> Self {
        let n = shape.iter().product();
        Tensor { shape: shape.to_vec(), data: vec![v; n] }
    }

    pub fn shape(&self) -> &[usize] {
        &self.shape
    }

    pub fn ndim(&self) -> usize {
        self.shape.len()
    }

    pub fn len(&self) -> usize {
        self.data.len()
    }

    pub fn is_empty(&self) -> bool {
        self.data.is_empty()
    }

    pub fn data(&self) -> &[T] {
        &self.data
    }

    pub fn data_mut(&mut self) -> &mut [T] {
        &mut self.data
    }

    pub fn into_vec(self) -> Vec<T> {
        self.data
    }

    /// Reinterpret with a new shape of equal element count.
    pub fn reshape(mut self, shape: &[usize]) -> Self {
        assert_eq!(shape.iter().product::<usize>(), self.data.len());
        self.shape = shape.to_vec();
        self
    }

    /// Row `i` of a 2-D tensor.
    pub fn row(&self, i: usize) -> &[T] {
        assert_eq!(self.ndim(), 2, "row() on non-matrix");
        let cols = self.shape[1];
        &self.data[i * cols..(i + 1) * cols]
    }

    pub fn row_mut(&mut self, i: usize) -> &mut [T] {
        assert_eq!(self.ndim(), 2, "row_mut() on non-matrix");
        let cols = self.shape[1];
        &mut self.data[i * cols..(i + 1) * cols]
    }

    /// Flat offset of a multi-index.
    pub fn offset(&self, idx: &[usize]) -> usize {
        assert_eq!(idx.len(), self.shape.len());
        let mut off = 0;
        for (d, (&i, &s)) in idx.iter().zip(&self.shape).enumerate() {
            assert!(i < s, "index {i} out of bounds for dim {d} (size {s})");
            off = off * s + i;
        }
        off
    }

    pub fn at(&self, idx: &[usize]) -> T {
        self.data[self.offset(idx)]
    }

    pub fn set(&mut self, idx: &[usize], v: T) {
        let o = self.offset(idx);
        self.data[o] = v;
    }

    /// Transpose a 2-D tensor (materialized).
    pub fn transpose2(&self) -> Self {
        assert_eq!(self.ndim(), 2);
        let (r, c) = (self.shape[0], self.shape[1]);
        let mut out = Tensor::zeros(&[c, r]);
        for i in 0..r {
            for j in 0..c {
                out.data[j * r + i] = self.data[i * c + j];
            }
        }
        out
    }
}

impl Tensor<f32> {
    /// Map elementwise.
    pub fn map(&self, f: impl Fn(f32) -> f32) -> Self {
        Tensor {
            shape: self.shape.clone(),
            data: self.data.iter().map(|&x| f(x)).collect(),
        }
    }

    /// Largest |x|.
    pub fn abs_max(&self) -> f32 {
        self.data.iter().fold(0f32, |m, &x| m.max(x.abs()))
    }

    /// Mean squared error vs another tensor of the same shape.
    pub fn mse(&self, other: &Self) -> f64 {
        assert_eq!(self.shape, other.shape);
        if self.data.is_empty() {
            return 0.0;
        }
        self.data
            .iter()
            .zip(&other.data)
            .map(|(&a, &b)| ((a - b) as f64).powi(2))
            .sum::<f64>()
            / self.data.len() as f64
    }

    /// Write raw little-endian f32s with a tiny header (shape) — the
    /// checkpoint format shared by train (PJRT) and serve paths.
    pub fn write_to(&self, w: &mut impl std::io::Write) -> std::io::Result<()> {
        w.write_all(&(self.shape.len() as u32).to_le_bytes())?;
        for &s in &self.shape {
            w.write_all(&(s as u32).to_le_bytes())?;
        }
        for &x in &self.data {
            w.write_all(&x.to_le_bytes())?;
        }
        Ok(())
    }

    pub fn read_from(r: &mut impl std::io::Read) -> std::io::Result<Self> {
        let mut b4 = [0u8; 4];
        r.read_exact(&mut b4)?;
        let ndim = u32::from_le_bytes(b4) as usize;
        if ndim > 8 {
            return Err(std::io::Error::new(
                std::io::ErrorKind::InvalidData,
                format!("implausible ndim {ndim}"),
            ));
        }
        let mut shape = Vec::with_capacity(ndim);
        for _ in 0..ndim {
            r.read_exact(&mut b4)?;
            shape.push(u32::from_le_bytes(b4) as usize);
        }
        let n: usize = shape.iter().product();
        let mut data = vec![0f32; n];
        for v in data.iter_mut() {
            r.read_exact(&mut b4)?;
            *v = f32::from_le_bytes(b4);
        }
        Ok(Tensor { shape, data })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn zeros_and_shape() {
        let t: TensorF = Tensor::zeros(&[2, 3]);
        assert_eq!(t.shape(), &[2, 3]);
        assert_eq!(t.len(), 6);
        assert!(t.data().iter().all(|&x| x == 0.0));
    }

    #[test]
    fn indexing_row_major() {
        let t = Tensor::from_vec(&[2, 3], vec![0, 1, 2, 3, 4, 5]);
        assert_eq!(t.at(&[0, 0]), 0);
        assert_eq!(t.at(&[0, 2]), 2);
        assert_eq!(t.at(&[1, 0]), 3);
        assert_eq!(t.row(1), &[3, 4, 5]);
    }

    #[test]
    #[should_panic(expected = "out of bounds")]
    fn oob_panics() {
        let t: TensorI = Tensor::zeros(&[2, 2]);
        t.at(&[2, 0]);
    }

    #[test]
    fn transpose_roundtrip() {
        let t = Tensor::from_vec(&[2, 3], vec![1, 2, 3, 4, 5, 6]);
        let tt = t.transpose2();
        assert_eq!(tt.shape(), &[3, 2]);
        assert_eq!(tt.at(&[0, 1]), 4);
        assert_eq!(tt.transpose2(), t);
    }

    #[test]
    fn reshape_preserves_data() {
        let t = Tensor::from_vec(&[4], vec![1.0f32, 2.0, 3.0, 4.0]);
        let t2 = t.clone().reshape(&[2, 2]);
        assert_eq!(t2.at(&[1, 0]), 3.0);
    }

    #[test]
    fn abs_max_and_mse() {
        let a = Tensor::from_vec(&[3], vec![1.0f32, -5.0, 2.0]);
        let b = Tensor::from_vec(&[3], vec![1.0f32, -5.0, 4.0]);
        assert_eq!(a.abs_max(), 5.0);
        assert!((a.mse(&b) - 4.0 / 3.0).abs() < 1e-9);
    }

    #[test]
    fn io_roundtrip() {
        let t = Tensor::from_vec(&[2, 2], vec![1.5f32, -2.5, 3.25, 0.0]);
        let mut buf = Vec::new();
        t.write_to(&mut buf).unwrap();
        let back = Tensor::read_from(&mut &buf[..]).unwrap();
        assert_eq!(back, t);
    }

    #[test]
    fn io_rejects_garbage() {
        let garbage = vec![0xFFu8; 16];
        assert!(Tensor::<f32>::read_from(&mut &garbage[..]).is_err());
    }
}
