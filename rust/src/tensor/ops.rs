//! Float reference operations over [`Tensor`]: GEMM, softmax, norms and
//! elementwise math. These are the *reference* numerics — the QRazor
//! integer path (`crate::sdr::gemm`) is validated against them, and the
//! Rust model inference uses them on dequantized lattices.

use super::Tensor;
use crate::util::threadpool::parallel_for;

/// C = A(m×k) · B(k×n), blocked and parallelized over rows of A.
pub fn matmul(a: &Tensor<f32>, b: &Tensor<f32>) -> Tensor<f32> {
    assert_eq!(a.ndim(), 2);
    assert_eq!(b.ndim(), 2);
    let (m, k) = (a.shape()[0], a.shape()[1]);
    let (k2, n) = (b.shape()[0], b.shape()[1]);
    assert_eq!(k, k2, "matmul inner dims {k} vs {k2}");
    let mut c = Tensor::zeros(&[m, n]);
    // Exclusive row slices handed out by index — safe, no aliasing.
    struct SendPtr(*mut f32);
    unsafe impl Sync for SendPtr {}
    impl SendPtr {
        fn get(&self) -> *mut f32 {
            self.0
        }
    }
    let cptr = SendPtr(c.data_mut().as_mut_ptr());
    let (adata, bdata) = (a.data(), b.data());
    parallel_for(m, |i| {
        let arow = &adata[i * k..(i + 1) * k];
        let crow = unsafe { std::slice::from_raw_parts_mut(cptr.get().add(i * n), n) };
        // ikj loop order: stream B rows, accumulate into C row (cache-friendly).
        for (p, &av) in arow.iter().enumerate() {
            if av == 0.0 {
                continue;
            }
            let brow = &bdata[p * n..(p + 1) * n];
            for (cj, &bv) in crow.iter_mut().zip(brow) {
                *cj += av * bv;
            }
        }
    });
    c
}

/// C = A(m×k) · Bᵀ where B is given as (n×k) — the natural layout for
/// attention scores (Q·Kᵀ) and for weight matrices stored row-major per
/// output channel.
pub fn matmul_bt(a: &Tensor<f32>, b: &Tensor<f32>) -> Tensor<f32> {
    assert_eq!(a.ndim(), 2);
    assert_eq!(b.ndim(), 2);
    let (m, k) = (a.shape()[0], a.shape()[1]);
    let (n, k2) = (b.shape()[0], b.shape()[1]);
    assert_eq!(k, k2, "matmul_bt inner dims {k} vs {k2}");
    let mut c = Tensor::zeros(&[m, n]);
    struct SendPtr(*mut f32);
    unsafe impl Sync for SendPtr {}
    impl SendPtr {
        fn get(&self) -> *mut f32 {
            self.0
        }
    }
    let cptr = SendPtr(c.data_mut().as_mut_ptr());
    let (adata, bdata) = (a.data(), b.data());
    parallel_for(m, |i| {
        let arow = &adata[i * k..(i + 1) * k];
        let crow = unsafe { std::slice::from_raw_parts_mut(cptr.get().add(i * n), n) };
        for (j, cj) in crow.iter_mut().enumerate() {
            let brow = &bdata[j * k..(j + 1) * k];
            let mut acc = 0f32;
            for (&x, &y) in arow.iter().zip(brow) {
                acc += x * y;
            }
            *cj = acc;
        }
    });
    c
}

/// In-place row-wise softmax over the last dim of a 2-D tensor.
pub fn softmax_rows(x: &mut Tensor<f32>) {
    assert_eq!(x.ndim(), 2);
    let cols = x.shape()[1];
    for row in x.data_mut().chunks_mut(cols) {
        let max = row.iter().fold(f32::NEG_INFINITY, |m, &v| m.max(v));
        let mut sum = 0f32;
        for v in row.iter_mut() {
            *v = (*v - max).exp();
            sum += *v;
        }
        let inv = 1.0 / sum;
        for v in row.iter_mut() {
            *v *= inv;
        }
    }
}

/// Row-wise log-softmax (numerically stable), returning a new tensor.
pub fn log_softmax_rows(x: &Tensor<f32>) -> Tensor<f32> {
    assert_eq!(x.ndim(), 2);
    let cols = x.shape()[1];
    let mut out = x.clone();
    for row in out.data_mut().chunks_mut(cols) {
        let max = row.iter().fold(f32::NEG_INFINITY, |m, &v| m.max(v));
        let lse = row.iter().map(|&v| ((v - max) as f64).exp()).sum::<f64>().ln() as f32 + max;
        for v in row.iter_mut() {
            *v -= lse;
        }
    }
    out
}

/// RMSNorm over the last dim: x * w / rms(x).
pub fn rmsnorm(x: &[f32], w: &[f32], eps: f32, out: &mut [f32]) {
    assert_eq!(x.len(), w.len());
    assert_eq!(x.len(), out.len());
    let ms = x.iter().map(|&v| (v as f64) * (v as f64)).sum::<f64>() / x.len() as f64;
    let inv = 1.0 / ((ms as f32 + eps).sqrt());
    for ((o, &xi), &wi) in out.iter_mut().zip(x).zip(w) {
        *o = xi * inv * wi;
    }
}

/// SiLU activation x·σ(x).
pub fn silu(x: f32) -> f32 {
    x / (1.0 + (-x).exp())
}

/// a += b (elementwise).
pub fn add_assign(a: &mut Tensor<f32>, b: &Tensor<f32>) {
    assert_eq!(a.shape(), b.shape());
    for (x, &y) in a.data_mut().iter_mut().zip(b.data()) {
        *x += y;
    }
}

/// Argmax over a slice.
pub fn argmax(xs: &[f32]) -> usize {
    let mut best = 0;
    for (i, &v) in xs.iter().enumerate() {
        if v > xs[best] {
            best = i;
        }
    }
    best
}

#[cfg(test)]
mod tests {
    use super::*;

    fn t(shape: &[usize], v: Vec<f32>) -> Tensor<f32> {
        Tensor::from_vec(shape, v)
    }

    #[test]
    fn matmul_known() {
        let a = t(&[2, 2], vec![1.0, 2.0, 3.0, 4.0]);
        let b = t(&[2, 2], vec![5.0, 6.0, 7.0, 8.0]);
        let c = matmul(&a, &b);
        assert_eq!(c.data(), &[19.0, 22.0, 43.0, 50.0]);
    }

    #[test]
    fn matmul_bt_matches_matmul() {
        use crate::util::rng::Rng;
        let mut rng = Rng::new(1);
        let mut a = Tensor::zeros(&[7, 13]);
        let mut b = Tensor::zeros(&[13, 5]);
        rng.fill_normal(a.data_mut(), 0.0, 1.0);
        rng.fill_normal(b.data_mut(), 0.0, 1.0);
        let c1 = matmul(&a, &b);
        let c2 = matmul_bt(&a, &b.transpose2());
        for (x, y) in c1.data().iter().zip(c2.data()) {
            assert!((x - y).abs() < 1e-4, "{x} vs {y}");
        }
    }

    #[test]
    fn softmax_rows_sum_to_one() {
        let mut x = t(&[2, 3], vec![1.0, 2.0, 3.0, -1.0, 0.0, 1.0]);
        softmax_rows(&mut x);
        for i in 0..2 {
            let s: f32 = x.row(i).iter().sum();
            assert!((s - 1.0).abs() < 1e-6);
            assert!(x.row(i).iter().all(|&v| v > 0.0));
        }
        // monotone: bigger logit -> bigger prob
        assert!(x.at(&[0, 2]) > x.at(&[0, 1]));
    }

    #[test]
    fn softmax_stable_for_huge_logits() {
        let mut x = t(&[1, 3], vec![1000.0, 1001.0, 999.0]);
        softmax_rows(&mut x);
        assert!(x.data().iter().all(|v| v.is_finite()));
        let s: f32 = x.data().iter().sum();
        assert!((s - 1.0).abs() < 1e-6);
    }

    #[test]
    fn log_softmax_consistency() {
        let x = t(&[1, 4], vec![0.5, -0.3, 2.0, 1.0]);
        let ls = log_softmax_rows(&x);
        let total: f32 = ls.data().iter().map(|&v| v.exp()).sum();
        assert!((total - 1.0).abs() < 1e-5);
    }

    #[test]
    fn rmsnorm_unit_scale() {
        let x = [3.0f32, 4.0];
        let w = [1.0f32, 1.0];
        let mut out = [0f32; 2];
        rmsnorm(&x, &w, 0.0, &mut out);
        // rms = sqrt((9+16)/2) = sqrt(12.5)
        let rms = 12.5f32.sqrt();
        assert!((out[0] - 3.0 / rms).abs() < 1e-6);
        assert!((out[1] - 4.0 / rms).abs() < 1e-6);
    }

    #[test]
    fn silu_values() {
        assert!((silu(0.0)).abs() < 1e-9);
        assert!(silu(10.0) > 9.99);
        assert!(silu(-10.0) > -0.01);
    }

    #[test]
    fn argmax_first_on_tie_breaks_low() {
        assert_eq!(argmax(&[1.0, 3.0, 3.0, 2.0]), 1);
        assert_eq!(argmax(&[5.0]), 0);
    }
}
