//! Configuration system: model architecture presets, quantization
//! configuration, and experiment settings. JSON-serializable so the
//! launcher, the AOT pipeline (python side reads the same file) and the
//! benches share one source of truth.

use crate::util::json::Json;

/// Transformer architecture (LLaMA-style: RMSNorm, RoPE, SwiGLU, GQA).
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct ModelConfig {
    pub name: String,
    pub vocab: usize,
    pub dim: usize,
    pub layers: usize,
    pub heads: usize,
    /// KV heads (< heads ⇒ grouped-query attention, the Mistral-style
    /// second architecture of Table 10).
    pub kv_heads: usize,
    /// SwiGLU hidden size.
    pub ffn_hidden: usize,
    pub seq_max: usize,
}

impl ModelConfig {
    pub fn head_dim(&self) -> usize {
        self.dim / self.heads
    }

    /// Parameter count (embeddings + blocks + head).
    pub fn param_count(&self) -> usize {
        let d = self.dim;
        let kv_dim = self.head_dim() * self.kv_heads;
        let per_layer = d * d // wq
            + d * kv_dim * 2 // wk, wv
            + d * d // wo
            + 2 * d * self.ffn_hidden // gate, up
            + self.ffn_hidden * d // down
            + 2 * d; // two rmsnorm gains
        self.vocab * d * 2 + self.layers * per_layer + d
    }

    /// Named presets. Dimensions are powers of two so the Hadamard
    /// baselines apply without padding.
    pub fn preset(name: &str) -> anyhow::Result<ModelConfig> {
        let c = match name {
            // CI-scale
            "nano" => ModelConfig {
                name: "nano".into(),
                vocab: 256,
                dim: 64,
                layers: 2,
                heads: 2,
                kv_heads: 2,
                ffn_hidden: 128,
                seq_max: 128,
            },
            // default experiment model (the "LLaMA-2-7B analog")
            "tiny" => ModelConfig {
                name: "tiny".into(),
                vocab: 512,
                dim: 256,
                layers: 4,
                heads: 4,
                kv_heads: 4,
                ffn_hidden: 512,
                seq_max: 256,
            },
            // the deeper variant (the "13B analog" — same family, more
            // capacity, mirroring the paper's scale column)
            "small" => ModelConfig {
                name: "small".into(),
                vocab: 512,
                dim: 512,
                layers: 6,
                heads: 8,
                kv_heads: 8,
                ffn_hidden: 1024,
                seq_max: 256,
            },
            // GQA architecture (the "Mistral-7B analog" for Table 10)
            "mistral-tiny" => ModelConfig {
                name: "mistral-tiny".into(),
                vocab: 512,
                dim: 256,
                layers: 4,
                heads: 8,
                kv_heads: 2,
                ffn_hidden: 512,
                seq_max: 256,
            },
            // ~100M-class config for the end-to-end driver at full tilt
            "medium" => ModelConfig {
                name: "medium".into(),
                vocab: 4096,
                dim: 768,
                layers: 12,
                heads: 12,
                kv_heads: 12,
                ffn_hidden: 2048,
                seq_max: 512,
            },
            other => anyhow::bail!("unknown model preset '{other}'"),
        };
        Ok(c)
    }

    pub fn to_json(&self) -> Json {
        Json::from_pairs(vec![
            ("name", Json::from(self.name.clone())),
            ("vocab", Json::from(self.vocab)),
            ("dim", Json::from(self.dim)),
            ("layers", Json::from(self.layers)),
            ("heads", Json::from(self.heads)),
            ("kv_heads", Json::from(self.kv_heads)),
            ("ffn_hidden", Json::from(self.ffn_hidden)),
            ("seq_max", Json::from(self.seq_max)),
        ])
    }

    pub fn from_json(j: &Json) -> anyhow::Result<ModelConfig> {
        let get = |k: &str| -> anyhow::Result<usize> {
            j.req(k)?
                .as_usize()
                .ok_or_else(|| anyhow::anyhow!("field '{k}' not a number"))
        };
        Ok(ModelConfig {
            name: j.req("name")?.as_str().unwrap_or("custom").to_string(),
            vocab: get("vocab")?,
            dim: get("dim")?,
            layers: get("layers")?,
            heads: get("heads")?,
            kv_heads: get("kv_heads")?,
            ffn_hidden: get("ffn_hidden")?,
            seq_max: get("seq_max")?,
        })
    }
}

/// Serving/experiment configuration for the coordinator.
#[derive(Clone, Debug, PartialEq)]
pub struct ServeConfig {
    /// Max concurrent sequences in a decode batch.
    pub max_batch: usize,
    /// Max new tokens per request.
    pub max_new_tokens: usize,
    /// Token budget per scheduler step (prefill chunking).
    pub max_step_tokens: usize,
    /// KV pool capacity in tokens.
    pub kv_pool_tokens: usize,
    /// Token rows per KV page — the admission/sharing quantum of the
    /// paged pool. 1 reproduces token-exact reservation accounting.
    pub kv_page_tokens: usize,
    /// SDR group size for the compressed KV pool (the fallback group
    /// for uniform scheme backends; razor-native policies carry their
    /// own per-layer KV groups).
    pub kv_group: usize,
    /// Speculative lookahead: draft tokens per round when the engine
    /// carries a draft model (0 = plain one-token-per-step decode).
    pub spec_k: usize,
    /// The serving (verify) quantization policy, in the policy DSL —
    /// recorded so a serve run emits one reproducible manifest; the
    /// CLI builds the target model from it.
    pub policy: String,
    /// The draft policy for speculative decoding — the razored
    /// low-fidelity twin of `policy` (used when `spec_k > 0`).
    pub draft_policy: String,
    /// Per-session `Token`-event ring capacity for the streaming
    /// surface: a client consuming slower than decode keeps at most
    /// this many undelivered `Token` events per session (oldest are
    /// dropped and counted in `ServeStats::events_dropped`;
    /// `Started`/`Finished` are always delivered). 0 = unbounded.
    pub event_ring: usize,
    /// Numeric-health deep-probe cadence + drift-alarm tuning
    /// (`sample_every_n_steps = 0` = probes off, the default).
    pub health: crate::obs::HealthConfig,
}

impl Default for ServeConfig {
    fn default() -> Self {
        ServeConfig {
            max_batch: 8,
            max_new_tokens: 64,
            max_step_tokens: 512,
            kv_pool_tokens: 16_384,
            kv_page_tokens: crate::model::kvcache::DEFAULT_PAGE_TOKENS,
            kv_group: 16,
            spec_k: 0,
            policy: "w4a4kv4:16".into(),
            draft_policy: "w4a4kv4:16".into(),
            event_ring: 1024,
            health: crate::obs::HealthConfig::default(),
        }
    }
}

impl ServeConfig {
    /// One reproducible JSON manifest for a serve run (includes the
    /// speculative lookahead and both policy names).
    pub fn to_json(&self) -> Json {
        Json::from_pairs(vec![
            ("max_batch", Json::from(self.max_batch)),
            ("max_new_tokens", Json::from(self.max_new_tokens)),
            ("max_step_tokens", Json::from(self.max_step_tokens)),
            ("kv_pool_tokens", Json::from(self.kv_pool_tokens)),
            ("kv_page_tokens", Json::from(self.kv_page_tokens)),
            ("kv_group", Json::from(self.kv_group)),
            ("spec_k", Json::from(self.spec_k)),
            ("policy", Json::from(self.policy.clone())),
            ("draft_policy", Json::from(self.draft_policy.clone())),
            ("event_ring", Json::from(self.event_ring)),
            ("health", self.health.to_json()),
        ])
    }

    pub fn from_json(j: &Json) -> anyhow::Result<ServeConfig> {
        let get = |k: &str| -> anyhow::Result<usize> {
            j.req(k)?
                .as_usize()
                .ok_or_else(|| anyhow::anyhow!("field '{k}' not a number"))
        };
        let get_str = |k: &str| -> anyhow::Result<String> {
            Ok(j.req(k)?
                .as_str()
                .ok_or_else(|| anyhow::anyhow!("field '{k}' not a string"))?
                .to_string())
        };
        Ok(ServeConfig {
            max_batch: get("max_batch")?,
            max_new_tokens: get("max_new_tokens")?,
            max_step_tokens: get("max_step_tokens")?,
            kv_pool_tokens: get("kv_pool_tokens")?,
            kv_page_tokens: get("kv_page_tokens")?,
            kv_group: get("kv_group")?,
            spec_k: get("spec_k")?,
            policy: get_str("policy")?,
            draft_policy: get_str("draft_policy")?,
            event_ring: get("event_ring")?,
            // Absent in manifests written before the health axis —
            // default (probes off) rather than erroring.
            health: match j.get("health") {
                None | Some(Json::Null) => crate::obs::HealthConfig::default(),
                Some(h) => crate::obs::HealthConfig::from_json(h)?,
            },
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn presets_resolve() {
        for p in ["nano", "tiny", "small", "mistral-tiny", "medium"] {
            let c = ModelConfig::preset(p).unwrap();
            assert_eq!(c.name, p);
            assert_eq!(c.dim % c.heads, 0);
            assert_eq!(c.heads % c.kv_heads, 0);
        }
        assert!(ModelConfig::preset("bogus").is_err());
    }

    #[test]
    fn param_counts_ordered_by_size() {
        let nano = ModelConfig::preset("nano").unwrap().param_count();
        let tiny = ModelConfig::preset("tiny").unwrap().param_count();
        let small = ModelConfig::preset("small").unwrap().param_count();
        let medium = ModelConfig::preset("medium").unwrap().param_count();
        assert!(nano < tiny && tiny < small && small < medium);
        assert!(medium > 80_000_000, "medium = {medium}");
    }

    #[test]
    fn json_roundtrip() {
        let c = ModelConfig::preset("mistral-tiny").unwrap();
        let j = Json::parse(&c.to_json().to_string()).unwrap();
        assert_eq!(ModelConfig::from_json(&j).unwrap(), c);
    }

    #[test]
    fn serve_config_json_roundtrip() {
        let c = ServeConfig {
            spec_k: 3,
            policy: "w4a8kv4:16".into(),
            draft_policy: "w4a4kv4:16;layers=0:w4a8".into(),
            event_ring: 32,
            ..Default::default()
        };
        let j = Json::parse(&c.to_json().to_string()).unwrap();
        let back = ServeConfig::from_json(&j).unwrap();
        assert_eq!(back, c);
        // missing fields are an error, not a silent default
        let partial = Json::from_pairs(vec![("max_batch", Json::from(4usize))]);
        assert!(ServeConfig::from_json(&partial).is_err());
    }

    #[test]
    fn gqa_preset_has_fewer_kv_heads() {
        let c = ModelConfig::preset("mistral-tiny").unwrap();
        assert!(c.kv_heads < c.heads);
        assert_eq!(c.head_dim(), 32);
    }
}
