//! Synthetic corpora standing in for WikiText-2 and Lambada-OpenAI.
//!
//! * [`wiki_corpus`] — a Zipf-distributed topic-Markov word model:
//!   word frequencies follow a power law, word order follows per-topic
//!   bigram tendencies, sentences and paragraphs have realistic length
//!   distributions. Learnable structure at several scales, like
//!   encyclopedic text. Used for calibration and the main perplexity
//!   column.
//! * [`lambada_corpus`] — long-range-dependency passages: a protagonist
//!   noun is introduced early and *must* be the final word of the
//!   passage (the Lambada task construction), so models are rewarded
//!   for carrying context across the whole sequence — the regime where
//!   KV-cache quantization error shows up.
//!
//! Both are deterministic in the seed; different seeds give disjoint
//! train/eval splits.

use crate::util::rng::Rng;

/// A synthetic vocabulary of pronounceable words, deterministic.
pub fn make_word_list(n: usize, rng: &mut Rng) -> Vec<String> {
    const ONSETS: &[&str] = &[
        "b", "br", "c", "ch", "d", "dr", "f", "fl", "g", "gr", "h", "j", "k", "l", "m", "n",
        "p", "pl", "pr", "qu", "r", "s", "sh", "sl", "st", "t", "th", "tr", "v", "w", "z",
    ];
    const VOWELS: &[&str] = &["a", "e", "i", "o", "u", "ai", "ea", "ou"];
    const CODAS: &[&str] = &["", "n", "r", "s", "t", "l", "m", "nd", "st", "ck"];
    let mut words = Vec::with_capacity(n);
    let mut seen = std::collections::HashSet::new();
    while words.len() < n {
        let syllables = 1 + rng.index(3);
        let mut w = String::new();
        for _ in 0..syllables {
            w.push_str(*rng.choose(ONSETS));
            w.push_str(*rng.choose(VOWELS));
        }
        w.push_str(*rng.choose(CODAS));
        if seen.insert(w.clone()) {
            words.push(w);
        }
    }
    words
}

/// The shared synthetic vocabulary for a world seed — both corpora and
/// the task generators draw from it so train/eval/task text live in one
/// distribution (splitting one corpus replaces WikiText's train/valid
/// split).
pub fn world_words(seed: u64) -> Vec<String> {
    make_word_list(2_000, &mut Rng::new(seed).split(1))
}

/// Zipf-Markov "wiki" corpus: `n_words` total words of text.
pub fn wiki_corpus(n_words: usize, seed: u64) -> String {
    let mut rng = Rng::new(seed);
    let vocab_n = 2_000;
    let words = world_words(seed);
    let n_topics = 16;
    // per-topic preferred successor offsets: crude bigram structure
    let topic_shift: Vec<usize> = (0..n_topics).map(|_| rng.index(vocab_n)).collect();
    let mut out = String::new();
    let mut topic = rng.index(n_topics);
    let mut prev_rank = rng.zipf(vocab_n, 1.1);
    let mut words_emitted = 0;
    let mut sentence_len = 0;
    let mut para_sentences = 0;
    while words_emitted < n_words {
        // topic drift at paragraph boundaries
        let rank = if rng.chance(0.85) {
            // bigram-ish: successor strongly correlated with prev via
            // the topic shift — low conditional entropy so small models
            // learn real structure (tasks stay discriminative)
            (prev_rank + topic_shift[topic] + rng.zipf(12, 1.4)) % vocab_n
        } else {
            rng.zipf(vocab_n, 1.1)
        };
        out.push_str(&words[rank]);
        words_emitted += 1;
        sentence_len += 1;
        prev_rank = rank;
        let end_sentence = sentence_len >= 4 && rng.chance(0.18);
        if end_sentence {
            out.push('.');
            sentence_len = 0;
            para_sentences += 1;
            if para_sentences >= 3 && rng.chance(0.3) {
                out.push('\n');
                para_sentences = 0;
                topic = rng.index(n_topics);
            } else {
                out.push(' ');
            }
        } else {
            out.push(' ');
        }
    }
    out
}

/// One Lambada-style passage: protagonist introduced early, repeated in
/// the middle, and required as the final word.
pub fn lambada_passage(rng: &mut Rng, words: &[String]) -> String {
    let protagonist = rng.choose(words).clone();
    let mut s = String::new();
    let intro_len = 6 + rng.index(6);
    for _ in 0..intro_len {
        s.push_str(&words[rng.zipf(words.len(), 1.05)]);
        s.push(' ');
    }
    s.push_str(&protagonist);
    s.push_str(". ");
    // middle: mention the protagonist again among distractors
    let mid_sentences = 2 + rng.index(3);
    for _ in 0..mid_sentences {
        let len = 5 + rng.index(5);
        for i in 0..len {
            if i == len / 2 && rng.chance(0.6) {
                s.push_str(&protagonist);
            } else {
                s.push_str(&words[rng.zipf(words.len(), 1.05)]);
            }
            s.push(' ');
        }
        s.push_str(". ");
    }
    // final sentence ends with the protagonist (the prediction target)
    let tail = 4 + rng.index(4);
    for _ in 0..tail {
        s.push_str(&words[rng.zipf(words.len(), 1.05)]);
        s.push(' ');
    }
    s.push_str(&protagonist);
    s.push('.');
    s
}

/// Lambada-style corpus of `n_passages` passages drawn from the world
/// vocabulary of `world_seed` (so a model trained on the wiki corpus
/// shares its token distribution); `seed` varies the passages.
pub fn lambada_corpus(n_passages: usize, world_seed: u64, seed: u64) -> String {
    let mut rng = Rng::new(seed ^ 0x1A3BADA);
    // the head of the world vocabulary = the frequent words
    let words: Vec<String> = world_words(world_seed).into_iter().take(400).collect();
    let mut out = String::new();
    for _ in 0..n_passages {
        out.push_str(&lambada_passage(&mut rng, &words));
        out.push('\n');
    }
    out
}

/// Split a corpus into train/eval texts at a word boundary
/// (`eval_frac` of the words go to eval — the WikiText-style split).
pub fn split_corpus(text: &str, eval_frac: f64) -> (String, String) {
    let words: Vec<&str> = text.split_inclusive(' ').collect();
    let cut = ((words.len() as f64) * (1.0 - eval_frac)) as usize;
    (words[..cut].concat(), words[cut..].concat())
}

/// Pack a token stream into fixed-length training sequences.
pub fn pack_sequences(tokens: &[u32], seq_len: usize) -> Vec<Vec<u32>> {
    tokens
        .chunks(seq_len)
        .filter(|c| c.len() == seq_len)
        .map(|c| c.to_vec())
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn word_list_unique_and_sized() {
        let mut rng = Rng::new(1);
        let words = make_word_list(500, &mut rng);
        assert_eq!(words.len(), 500);
        let set: std::collections::HashSet<_> = words.iter().collect();
        assert_eq!(set.len(), 500);
        assert!(words.iter().all(|w| !w.is_empty() && w.is_ascii()));
    }

    #[test]
    fn wiki_corpus_deterministic_and_sized() {
        let a = wiki_corpus(500, 42);
        let b = wiki_corpus(500, 42);
        assert_eq!(a, b);
        let c = wiki_corpus(500, 43);
        assert_ne!(a, c);
        let n_words = a.split_whitespace().count();
        assert!((450..650).contains(&n_words), "{n_words} words");
    }

    #[test]
    fn wiki_corpus_is_zipfian() {
        let text = wiki_corpus(20_000, 7);
        let mut counts: std::collections::HashMap<&str, usize> = Default::default();
        for w in text.split_whitespace() {
            let w = w.trim_end_matches(['.', '\n']);
            if !w.is_empty() {
                *counts.entry(w).or_insert(0) += 1;
            }
        }
        let mut freqs: Vec<usize> = counts.values().copied().collect();
        freqs.sort_unstable_by(|a, b| b.cmp(a));
        // head token should be far more frequent than the 100th
        assert!(freqs[0] > freqs.get(100).copied().unwrap_or(1) * 5);
    }

    #[test]
    fn lambada_passages_end_with_repeated_word() {
        let mut rng = Rng::new(3);
        let words = make_word_list(200, &mut rng.split(1));
        for _ in 0..20 {
            let p = lambada_passage(&mut rng, &words);
            let last = p
                .trim_end_matches('.')
                .split_whitespace()
                .last()
                .unwrap()
                .to_string();
            // final word must appear earlier in the passage too
            let earlier = p[..p.len() - last.len() - 1].contains(&last);
            assert!(earlier, "passage {p}");
        }
    }

    #[test]
    fn pack_sequences_drops_ragged_tail() {
        let toks: Vec<u32> = (0..105).collect();
        let seqs = pack_sequences(&toks, 32);
        assert_eq!(seqs.len(), 3);
        assert!(seqs.iter().all(|s| s.len() == 32));
        assert_eq!(seqs[2][31], 95);
    }

    #[test]
    fn split_corpus_partitions_words() {
        let text = wiki_corpus(2_000, 3);
        let (train, eval) = split_corpus(&text, 0.25);
        assert!(!train.is_empty() && !eval.is_empty());
        let n = |s: &str| s.split_whitespace().count();
        let frac = n(&eval) as f64 / (n(&train) + n(&eval)) as f64;
        assert!((0.2..0.3).contains(&frac), "eval frac {frac}");
    }

    #[test]
    fn lambada_shares_world_vocabulary() {
        let wiki = wiki_corpus(3_000, 9);
        let lam = lambada_corpus(10, 9, 1);
        let wiki_words: std::collections::HashSet<&str> = wiki
            .split_whitespace()
            .map(|w| w.trim_end_matches(['.', '\n']))
            .collect();
        let total = lam.split_whitespace().count();
        let shared = lam
            .split_whitespace()
            .map(|w| w.trim_end_matches(['.', '\n']))
            .filter(|w| wiki_words.contains(w))
            .count();
        assert!(
            shared as f64 / total as f64 > 0.6,
            "only {shared}/{total} lambada words in wiki vocab"
        );
    }

    #[test]
    fn corpora_are_tokenizable() {
        let text = wiki_corpus(2_000, 11);
        let tok = crate::data::tokenizer::Tokenizer::train(&text[..text.len().min(4000)], 512);
        let ids = tok.encode(&text[..500.min(text.len())]);
        assert!(!ids.is_empty());
        assert!(ids.iter().all(|&i| (i as usize) < tok.vocab_size()));
    }
}
