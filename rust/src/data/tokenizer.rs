//! Byte-pair-encoding (BPE-lite) tokenizer.
//!
//! Trained greedily on a corpus sample: start from the 256 byte tokens,
//! repeatedly merge the most frequent adjacent pair until the target
//! vocabulary size is reached. Deterministic, no external deps, and
//! fast enough to retrain per experiment seed. `encode ∘ decode = id`
//! is property-tested.

use std::collections::HashMap;

/// A trained tokenizer: byte alphabet + ordered merge rules.
#[derive(Clone, Debug)]
pub struct Tokenizer {
    /// merge rules in priority order: (left, right) -> new token id.
    merges: Vec<(u32, u32)>,
    /// id -> byte sequence.
    pieces: Vec<Vec<u8>>,
    merge_rank: HashMap<(u32, u32), usize>,
}

impl Tokenizer {
    /// Number of tokens in the vocabulary.
    pub fn vocab_size(&self) -> usize {
        self.pieces.len()
    }

    /// Train on `text` until the vocabulary reaches `vocab` tokens.
    pub fn train(text: &str, vocab: usize) -> Tokenizer {
        assert!(vocab >= 256, "vocab must cover the byte alphabet");
        let mut pieces: Vec<Vec<u8>> = (0u16..256).map(|b| vec![b as u8]).collect();
        let mut merges = Vec::new();
        let mut ids: Vec<u32> = text.bytes().map(|b| b as u32).collect();
        while pieces.len() < vocab {
            // count adjacent pairs
            let mut counts: HashMap<(u32, u32), u64> = HashMap::new();
            for w in ids.windows(2) {
                *counts.entry((w[0], w[1])).or_insert(0) += 1;
            }
            // deterministic argmax: highest count, ties by smallest pair
            let best = counts
                .iter()
                .max_by_key(|(&pair, &c)| (c, std::cmp::Reverse(pair)))
                .map(|(&p, &c)| (p, c));
            let Some((pair, count)) = best else { break };
            if count < 2 {
                break; // nothing worth merging
            }
            let new_id = pieces.len() as u32;
            let mut piece = pieces[pair.0 as usize].clone();
            piece.extend_from_slice(&pieces[pair.1 as usize]);
            pieces.push(piece);
            merges.push(pair);
            // apply the merge to the working sequence
            ids = merge_sequence(&ids, pair, new_id);
        }
        let merge_rank = merges
            .iter()
            .enumerate()
            .map(|(i, &p)| (p, i))
            .collect();
        Tokenizer { merges, pieces, merge_rank }
    }

    /// Encode text to token ids by replaying merges in rank order.
    pub fn encode(&self, text: &str) -> Vec<u32> {
        let mut ids: Vec<u32> = text.bytes().map(|b| b as u32).collect();
        loop {
            // find the lowest-rank applicable merge
            let mut best: Option<(usize, usize)> = None; // (rank, pos)
            for (pos, w) in ids.windows(2).enumerate() {
                if let Some(&rank) = self.merge_rank.get(&(w[0], w[1])) {
                    if best.map(|(r, _)| rank < r).unwrap_or(true) {
                        best = Some((rank, pos));
                    }
                }
            }
            let Some((rank, _)) = best else { break };
            let pair = self.merges[rank];
            let new_id = 256 + rank as u32;
            ids = merge_sequence(&ids, pair, new_id);
        }
        ids
    }

    /// Decode token ids back to text (lossy only on invalid UTF-8,
    /// which our corpora never produce).
    pub fn decode(&self, ids: &[u32]) -> String {
        let mut bytes = Vec::new();
        for &id in ids {
            bytes.extend_from_slice(&self.pieces[id as usize]);
        }
        String::from_utf8_lossy(&bytes).into_owned()
    }
}

fn merge_sequence(ids: &[u32], pair: (u32, u32), new_id: u32) -> Vec<u32> {
    let mut out = Vec::with_capacity(ids.len());
    let mut i = 0;
    while i < ids.len() {
        if i + 1 < ids.len() && ids[i] == pair.0 && ids[i + 1] == pair.1 {
            out.push(new_id);
            i += 2;
        } else {
            out.push(ids[i]);
            i += 1;
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = "the cat sat on the mat. the cat ate the rat. \
                          the dog saw the cat and the cat ran.";

    #[test]
    fn train_grows_vocab() {
        let tok = Tokenizer::train(SAMPLE, 280);
        assert!(tok.vocab_size() > 256);
        assert!(tok.vocab_size() <= 280);
    }

    #[test]
    fn roundtrip_identity() {
        let tok = Tokenizer::train(SAMPLE, 300);
        for text in [SAMPLE, "the cat", "unseen words zqx!", ""] {
            assert_eq!(tok.decode(&tok.encode(text)), text);
        }
    }

    #[test]
    fn merges_compress() {
        let tok = Tokenizer::train(SAMPLE, 320);
        let ids = tok.encode(SAMPLE);
        assert!(
            ids.len() < SAMPLE.len() * 3 / 4,
            "{} tokens for {} bytes",
            ids.len(),
            SAMPLE.len()
        );
    }

    #[test]
    fn frequent_word_becomes_few_tokens() {
        let tok = Tokenizer::train(SAMPLE, 320);
        let the = tok.encode("the ");
        assert!(the.len() <= 2, "'the ' -> {the:?}");
    }

    #[test]
    fn deterministic() {
        let a = Tokenizer::train(SAMPLE, 300);
        let b = Tokenizer::train(SAMPLE, 300);
        assert_eq!(a.encode(SAMPLE), b.encode(SAMPLE));
    }

    #[test]
    fn prop_roundtrip_random_ascii() {
        use crate::util::quickcheck::{check, Config, Gen, IntRange, VecGen};
        let tok = Tokenizer::train(SAMPLE, 300);
        let gen = VecGen { elem: IntRange { lo: 32, hi: 126 }, min_len: 0, max_len: 200 };
        check("tokenizer-roundtrip", Config { cases: 100, ..Default::default() }, &gen, |bytes| {
            let text: String = bytes.iter().map(|&b| b as u8 as char).collect();
            tok.decode(&tok.encode(&text)) == text
        });
        // silence unused-import style warnings for Gen
        let mut rng = crate::util::rng::Rng::new(1);
        let _ = IntRange { lo: 0, hi: 1 }.generate(&mut rng);
    }
}
