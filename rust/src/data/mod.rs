//! Data substrate: synthetic corpora and the tokenizer.
//!
//! The paper calibrates and evaluates on WikiText-2 and Lambada-OpenAI;
//! neither is available offline, so [`corpus`] synthesizes two
//! distributionally distinct stand-ins (documented in DESIGN.md §1) and
//! [`tokenizer`] provides a BPE-lite tokenizer trained on them. All
//! generation is seed-deterministic.

pub mod corpus;
pub mod tokenizer;
