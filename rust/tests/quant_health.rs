//! Numeric-health acceptance suite: the observe-only contract (token
//! streams are byte-identical with health counters + probes on), the
//! zero-allocation guarantee of the disabled counter path (pinned
//! with a counting global allocator), drift-EWMA properties (a
//! monotone ramp alarms exactly once, a stationary series never
//! does), the escalation advisor's error-reduction claim measured on
//! calibration data, and cluster-merge ≡ single-shard-sums for the
//! mergeable health state. Runs on the nano preset; no artifacts
//! needed.

use std::alloc::{GlobalAlloc, Layout, System};
use std::cell::Cell;
use std::sync::{Mutex, MutexGuard};

use qrazor::config::{ModelConfig, ServeConfig};
use qrazor::coordinator::{Engine, Sampling};
use qrazor::model::quantized::{calibrate, QuantModel};
use qrazor::model::ModelWeights;
use qrazor::obs::{self, HealthConfig, HealthStats, SiteScope};
use qrazor::policy::health::{advise, DriftDetector, HealthReport};
use qrazor::policy::{QuantPolicy, Site};
use qrazor::util::rng::Rng;

// ---------------------------------------------------------------- //
// counting allocator: per-thread counters, so libtest's parallel
// workers never pollute each other's reading (same pattern as the
// telemetry suite).
// ---------------------------------------------------------------- //

thread_local! {
    static THREAD_ALLOCS: Cell<u64> = const { Cell::new(0) };
}

struct CountingAlloc;

unsafe impl GlobalAlloc for CountingAlloc {
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        THREAD_ALLOCS.with(|c| c.set(c.get() + 1));
        System.alloc(layout)
    }

    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        System.dealloc(ptr, layout)
    }
}

#[global_allocator]
static ALLOC: CountingAlloc = CountingAlloc;

fn allocs_on_this_thread() -> u64 {
    THREAD_ALLOCS.with(|c| c.get())
}

/// The health flags and counter tables are process-global; every test
/// that flips or reads them serializes here.
fn health_guard() -> MutexGuard<'static, ()> {
    static LOCK: Mutex<()> = Mutex::new(());
    LOCK.lock().unwrap_or_else(|e| e.into_inner())
}

// ---------------------------------------------------------------- //
// builders
// ---------------------------------------------------------------- //

/// Nano model under the razor policy; `attenuate` shrinks the frozen
/// calibration amax to emulate a live distribution that drifted
/// `1/factor`× past the calibrated range.
fn build(seed: u64, attenuate: Option<f32>) -> QuantModel {
    let cfg = ModelConfig::preset("nano").unwrap();
    let w = ModelWeights::init_random(&cfg, seed);
    let mut rng = Rng::new(seed + 1);
    let seqs: Vec<Vec<u32>> = (0..4)
        .map(|_| (0..24).map(|_| rng.below(cfg.vocab as u64) as u32).collect())
        .collect();
    let mut cal = calibrate(&w, &seqs);
    if let Some(f) = attenuate {
        cal.calibrator.attenuate(f);
    }
    QuantModel::build(&w, QuantPolicy::parse("w4a4kv4:16").unwrap(), &cal)
}

/// One deterministic greedy workload through a bare engine; returns
/// the per-request token streams (sorted by id) and the engine's
/// health state.
fn run_tokens(qm: QuantModel, health: HealthConfig) -> (Vec<Vec<u32>>, HealthStats) {
    let mut engine = Engine::new(
        qm,
        ServeConfig { max_batch: 4, max_new_tokens: 8, health, ..Default::default() },
    );
    let vocab = engine.model.config.vocab as u64;
    let mut rng = Rng::new(9);
    for _ in 0..6 {
        let len = 3 + rng.index(10);
        let prompt: Vec<u32> = (0..len).map(|_| rng.below(vocab) as u32).collect();
        engine.submit(prompt, 8, Sampling::Greedy);
    }
    let mut done = engine.run_to_completion();
    assert_eq!(done.len(), 6);
    done.sort_by_key(|r| r.id);
    (done.into_iter().map(|r| r.tokens).collect(), engine.metrics.health.clone())
}

// ---------------------------------------------------------------- //
// observe-only + disabled-path contracts
// ---------------------------------------------------------------- //

/// Health counters and per-step deep probes must never perturb the
/// compute: the token streams with everything on are byte-identical
/// to the streams with everything off.
#[test]
fn health_on_streams_byte_identical() {
    let _g = health_guard();
    obs::health_reset();
    obs::set_health(false);
    let (base, off_stats) = run_tokens(build(3, None), HealthConfig::default());
    assert_eq!(off_stats.probe_steps, 0, "probes default off");

    obs::health_reset();
    obs::set_health(true);
    let (probed, on_stats) = run_tokens(
        build(3, None),
        HealthConfig { sample_every_n_steps: 1, ..Default::default() },
    );
    obs::set_health(false);
    assert!(on_stats.probe_steps > 0, "every step probed");
    assert!(on_stats.probe_samples > 0, "probes saw sites");
    assert_eq!(base, probed, "health instrumentation must be observe-only");
}

/// With the counters off, the razoring choke-point hooks and the site
/// scope guard are one relaxed atomic load / a TLS swap — never an
/// allocation.
#[test]
fn disabled_path_allocates_nothing() {
    let _g = health_guard();
    obs::set_health(false);
    obs::set_probe(false);
    // Warm the thread-locals outside the measured window.
    {
        let _s = SiteScope::enter(0, Site::Act);
        qrazor::obs::health::note_razor_group(3, 16, 2, 1);
    }
    let before = allocs_on_this_thread();
    for i in 0..1000usize {
        let _s = SiteScope::enter(i % 4, Site::Act);
        qrazor::obs::health::note_razor_group((i % 16) as u8, 16, 2, 1);
        qrazor::obs::health::note_clips(3);
        assert!(!obs::probe_enabled());
    }
    assert_eq!(
        allocs_on_this_thread() - before,
        0,
        "disabled health path must not allocate"
    );
}

// ---------------------------------------------------------------- //
// drift-EWMA properties
// ---------------------------------------------------------------- //

/// A monotone drift ramp crossing the threshold fires the alarm
/// exactly once (latched), for several ramp shapes.
#[test]
fn drift_ramp_alarms_exactly_once_across_seeds() {
    for seed in 1u64..=5 {
        let det = DriftDetector::new(HealthConfig::default());
        let mut stats = HealthStats::default();
        let slope = 0.03 + 0.02 * seed as f64;
        let mut fired = 0usize;
        for i in 0..80 {
            if det.observe_ratio(&mut stats, "ramp", 0.9 + slope * i as f64) {
                fired += 1;
            }
        }
        assert_eq!(fired, 1, "ramp (slope {slope:.2}) must alarm exactly once");
        assert_eq!(stats.drift_alarms, 1);
        assert!(stats.sites["ramp"].alarmed, "alarm latches");
    }
}

/// A stationary series bounded under the alarm ratio never alarms,
/// regardless of jitter.
#[test]
fn stationary_drift_never_alarms() {
    for seed in 1u64..=5 {
        let det = DriftDetector::new(HealthConfig::default());
        let mut stats = HealthStats::default();
        let mut rng = Rng::new(seed);
        for _ in 0..200 {
            let jitter = rng.below(1000) as f64 / 1000.0; // [0, 1)
            let fired = det.observe_ratio(&mut stats, "flat", 0.95 + 0.3 * jitter);
            assert!(!fired, "stationary drift must not alarm");
        }
        assert_eq!(stats.drift_alarms, 0);
        assert!(!stats.sites["flat"].alarmed);
    }
}

// ---------------------------------------------------------------- //
// escalation advisor
// ---------------------------------------------------------------- //

/// The advisor's suggested escalation must strictly reduce the
/// measured activation razoring error over the calibration samples —
/// the same metric the offline sensitivity builder ranks with.
#[test]
fn advisor_escalation_reduces_measured_error() {
    let cfg = ModelConfig::preset("nano").unwrap();
    let w = ModelWeights::init_random(&cfg, 3);
    let mut rng = Rng::new(4);
    let seqs: Vec<Vec<u32>> = (0..4)
        .map(|_| (0..24).map(|_| rng.below(cfg.vocab as u64) as u32).collect())
        .collect();
    let cal = calibrate(&w, &seqs);
    let policy = QuantPolicy::parse("w4a4kv4:16").unwrap();
    let alarmed = vec!["l0.attn_in".to_string(), "l1.ffn_in".to_string()];
    let advice = advise(&policy, &alarmed).expect("act alarms must produce advice");
    assert_eq!(advice.act_layers, vec![0, 1]);
    let before = policy.act_calibration_error(&cal, cfg.layers);
    let after = advice.escalated.act_calibration_error(&cal, cfg.layers);
    assert!(
        after < before,
        "escalation must strictly reduce razoring error: {before:.4} -> {after:.4}"
    );
    // The rendered DSL is the whole fix: it parses back to the same
    // canonical policy.
    let reparsed = QuantPolicy::parse(&advice.dsl).expect("advice DSL parses");
    assert_eq!(reparsed.to_string(), advice.escalated.to_string());
}

/// End to end: serving with stale frozen scales (attenuated 0.4×, a
/// ~2.5× live drift) must latch per-site alarms and surface advice
/// through the report.
#[test]
fn stale_scales_trip_alarms_and_advice() {
    let _g = health_guard();
    obs::health_reset();
    let (_, stats) = run_tokens(
        build(3, Some(0.4)),
        HealthConfig { sample_every_n_steps: 1, ..Default::default() },
    );
    assert!(stats.drift_alarms > 0, "stale scales must alarm");
    let policy = QuantPolicy::parse("w4a4kv4:16").unwrap();
    let rep = HealthReport::from_stats(&stats, &policy, 8);
    assert!(!rep.alarmed_sites.is_empty());
    assert!(rep.advice.is_some(), "alarms on a razor policy must produce advice");
}

// ---------------------------------------------------------------- //
// cluster merge ≡ single-shard sums
// ---------------------------------------------------------------- //

/// Merging two shards' health states equals the single-shard sums:
/// counters and histograms add, per-site samples add, peaks take the
/// max, alarms OR.
#[test]
fn cluster_merge_equals_single_shard_sums() {
    let det = DriftDetector::new(HealthConfig::default());
    let mut a = HealthStats::default();
    let mut b = HealthStats::default();
    let mut rng = Rng::new(11);
    for i in 0..40 {
        let d = 1.0 + rng.below(2000) as f64 / 1000.0; // [1, 3)
        let site = ["l0.attn_in", "l1.ffn_in", "l0.q"][i % 3];
        det.observe_ratio(if i % 2 == 0 { &mut a } else { &mut b }, site, d);
    }
    a.probe_steps = 20;
    b.probe_steps = 20;
    let mut merged = a.clone();
    merged.merge(&b);
    assert_eq!(merged.probe_steps, a.probe_steps + b.probe_steps);
    assert_eq!(merged.probe_samples, a.probe_samples + b.probe_samples);
    assert_eq!(merged.drift_alarms, a.drift_alarms + b.drift_alarms);
    assert_eq!(merged.drift.len(), a.drift.len() + b.drift.len());
    for (site, m) in merged.sites.iter() {
        let sa = a.sites.get(site);
        let sb = b.sites.get(site);
        let samples = |s: Option<&obs::SiteHealth>| s.map_or(0, |s| s.samples);
        let peak = |s: Option<&obs::SiteHealth>| s.map_or(0.0, |s| s.peak);
        let alarmed = |s: Option<&obs::SiteHealth>| s.is_some_and(|s| s.alarmed);
        assert_eq!(m.samples, samples(sa) + samples(sb), "site {site}");
        assert_eq!(m.peak, peak(sa).max(peak(sb)), "site {site}");
        assert_eq!(m.alarmed, alarmed(sa) || alarmed(sb), "site {site}");
    }
    // An empty shard is the merge identity.
    let mut id = a.clone();
    id.merge(&HealthStats::default());
    assert_eq!(id, a);
}
