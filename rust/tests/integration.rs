//! Cross-module integration tests: the full pipeline (PJRT train →
//! quantize → evaluate → serve), the quantized-logits artifact, and
//! invariants that only show up when the pieces compose.
//!
//! All tests skip gracefully when `artifacts/` is missing so `cargo
//! test` stays green pre-`make artifacts`; CI runs `make test`, which
//! builds artifacts first.

use qrazor::baselines::{Fp16, QRazor};
use qrazor::config::ServeConfig;
use qrazor::coordinator::request::Sampling;
use qrazor::coordinator::Engine;
use qrazor::eval::harness::{build_experiment, EvalScale};
use qrazor::eval::perplexity::perplexity;
use qrazor::model::quantized::QuantModel;
use qrazor::runtime::{default_dir, Manifest, Runtime};

fn have_artifacts() -> bool {
    default_dir().join("meta.json").exists()
}

/// The whole system, quick scale: train via PJRT (or reuse checkpoint),
/// quantize, check the quantization-noise ordering on held-out ppl,
/// then serve a batch of requests from the same quantized model.
#[test]
fn full_pipeline_train_quantize_eval_serve() {
    if !have_artifacts() {
        eprintln!("skipping: run `make artifacts`");
        return;
    }
    let scale = EvalScale::quick();
    let exp = build_experiment("nano", scale, 42).expect("experiment");

    // quantization-noise ordering on held-out data
    let fp = exp.eval_fp();
    let a8 = exp.eval_scheme(Box::new(QRazor::w4a8(16)));
    let a4kv4_g128 = exp.eval_scheme(Box::new(QRazor::w4a4kv4(128)));
    assert!(fp.ppl_wiki > 1.0 && fp.ppl_wiki < 200.0, "fp ppl {}", fp.ppl_wiki);
    assert!(
        fp.ppl_wiki <= a8.ppl_wiki * 1.02,
        "fp {} must not lose to w4a8 {}",
        fp.ppl_wiki,
        a8.ppl_wiki
    );
    assert!(
        a8.ppl_wiki < a4kv4_g128.ppl_wiki,
        "w4a8 {} must beat w4a4kv4-g128 {}",
        a8.ppl_wiki,
        a4kv4_g128.ppl_wiki
    );

    // serve with the quantized model; all requests complete
    let qm = QuantModel::build(&exp.weights, Box::new(QRazor::w4a4kv4(16)), &exp.cal);
    let mut engine = Engine::new(
        qm,
        ServeConfig { max_batch: 4, max_new_tokens: 8, ..Default::default() },
    );
    for i in 0..6u32 {
        engine.submit(vec![1 + i % 40, 7, 9], 6, Sampling::Greedy);
    }
    let out = engine.run_to_completion();
    assert_eq!(out.len(), 6);
    assert!(out.iter().all(|r| r.tokens.len() == 6));
    assert!(engine.metrics.tokens_per_s() > 0.0);
}

/// The quantized-logits artifact (L1 Pallas kernels lowered inside the
/// L2 graph) loads, runs, and its outputs stay close to the FP artifact
/// — the serving-graph version of the accuracy experiments.
#[test]
fn w4a4_artifact_runs_and_tracks_fp() {
    if !have_artifacts() {
        eprintln!("skipping: run `make artifacts`");
        return;
    }
    let m = Manifest::load(&default_dir()).unwrap();
    let rt = Runtime::cpu().unwrap();
    let fp = rt.load_hlo(&m.artifact_path("lm_logits_fp").unwrap()).unwrap();
    let q = rt.load_hlo(&m.artifact_path("lm_logits_w4a4").unwrap()).unwrap();

    let w = qrazor::model::ModelWeights::init_random(&m.model, 3);
    let mut rng = qrazor::util::rng::Rng::new(4);
    let tokens: Vec<u32> = (0..m.eval_seq)
        .map(|_| rng.below(m.model.vocab as u64) as u32)
        .collect();
    let mut inputs = vec![
        qrazor::runtime::client::tokens_to_literal(&tokens, m.eval_batch, m.eval_seq).unwrap(),
    ];
    for (_, t) in w.to_named() {
        inputs.push(qrazor::runtime::client::tensor_to_literal(&t).unwrap());
    }
    let fp_out = fp.run(&inputs).unwrap();
    let q_out = q.run(&inputs).unwrap();
    let shape = [m.eval_seq, m.model.vocab];
    let fp_t = qrazor::runtime::client::literal_to_tensor(&fp_out[0], &shape).unwrap();
    let q_t = qrazor::runtime::client::literal_to_tensor(&q_out[0], &shape).unwrap();
    assert!(q_t.data().iter().all(|v| v.is_finite()));
    let rel = qrazor::baselines::rel_error(&fp_t, &q_t);
    assert!(rel > 0.0, "quantized artifact must differ from fp");
    assert!(rel < 1.0, "quantized artifact diverged: rel {rel}");
}

/// Batched serving equals sequential serving token-for-token under
/// greedy decoding even with SDR KV caches — continuous batching must
/// not perturb any sequence.
#[test]
fn batching_invariance_with_sdr_kv() {
    if !have_artifacts() {
        eprintln!("skipping: run `make artifacts`");
        return;
    }
    let scale = EvalScale::quick();
    let exp = build_experiment("nano", scale, 42).expect("experiment");
    let prompts: Vec<Vec<u32>> = vec![vec![3, 5, 8], vec![11, 2], vec![7, 7, 7, 7]];

    let engine = |batch: usize| {
        let qm = QuantModel::build(&exp.weights, Box::new(QRazor::w4a4kv4(16)), &exp.cal);
        Engine::new(
            qm,
            ServeConfig { max_batch: batch, max_new_tokens: 6, ..Default::default() },
        )
    };
    let mut batched = engine(4);
    for p in &prompts {
        batched.submit(p.clone(), 6, Sampling::Greedy);
    }
    let mut got = batched.run_to_completion();
    got.sort_by_key(|r| r.id);
    let mut solo_outs = Vec::new();
    for p in &prompts {
        let mut solo = engine(1);
        solo.submit(p.clone(), 6, Sampling::Greedy);
        solo_outs.push(solo.run_to_completion().remove(0));
    }
    for (a, b) in got.iter().zip(&solo_outs) {
        assert_eq!(a.tokens, b.tokens);
    }
}

/// FP16-scheme QuantModel and the raw FP forward produce identical
/// perplexity — the "scheme plumbing adds zero noise" guarantee every
/// table row relies on.
#[test]
fn fp16_scheme_is_transparent_end_to_end() {
    if !have_artifacts() {
        eprintln!("skipping: run `make artifacts`");
        return;
    }
    let scale = EvalScale::quick();
    let exp = build_experiment("nano", scale, 42).expect("experiment");
    let fp_direct = qrazor::model::FpModel { weights: exp.weights.clone() };
    let fp_scheme = QuantModel::build(&exp.weights, Box::new(Fp16), &exp.cal);
    let p1 = perplexity(&fp_direct, &exp.wiki_seqs);
    let p2 = perplexity(&fp_scheme, &exp.wiki_seqs);
    assert!(
        (p1 - p2).abs() / p1 < 1e-4,
        "scheme plumbing changed ppl: {p1} vs {p2}"
    );
}
