//! Loopback protocol-conformance suite for the network front-end:
//! SSE and JSON-lines framing, malformed-request 4xx mapping,
//! disconnect-cancel KV accounting draining to zero bytes, Prometheus
//! `/metrics` with per-tenant labels, tenant quota/rate 429s, and the
//! slow-reader session-buffer guard. Needs no artifacts; runs on the
//! nano preset against both `Server` and `ClusterServer` backends.

use std::sync::Arc;
use std::time::{Duration, Instant};

use qrazor::baselines::QRazor;
use qrazor::cluster::{ClusterConfig, ClusterServer};
use qrazor::config::{ModelConfig, ServeConfig};
use qrazor::coordinator::{Engine, Sampling, Server};
use qrazor::model::quantized::{calibrate, QuantModel};
use qrazor::model::ModelWeights;
use qrazor::net::{client, parse_tenants, HttpServer, NetConfig, TenantSpec};
use qrazor::util::json::Json;
use qrazor::util::rng::Rng;

fn model(seed: u64) -> Arc<QuantModel> {
    let cfg = ModelConfig::preset("nano").unwrap();
    let w = ModelWeights::init_random(&cfg, seed);
    let mut rng = Rng::new(seed + 1);
    let seqs: Vec<Vec<u32>> = (0..2)
        .map(|_| (0..16).map(|_| rng.below(cfg.vocab as u64) as u32).collect())
        .collect();
    let cal = calibrate(&w, &seqs);
    Arc::new(QuantModel::build(&w, Box::new(QRazor::w4a4kv4(16)), &cal))
}

/// Greedy baseline tokens straight off a bare engine.
fn baseline_tokens(model: &Arc<QuantModel>, prompt: Vec<u32>, max_new: usize) -> Vec<u32> {
    let mut e = Engine::new(Arc::clone(model), ServeConfig::default());
    e.submit(prompt, max_new, Sampling::Greedy);
    e.run_to_completion().pop().unwrap().tokens
}

fn wait_drained<A: qrazor::coordinator::ServeApi + Send + 'static>(http: &HttpServer<A>) {
    let deadline = Instant::now() + Duration::from_secs(20);
    loop {
        let st = http.stats();
        if st.in_flight() == 0 && st.occupancy.bytes == 0 {
            return;
        }
        assert!(Instant::now() < deadline, "server never drained: {st:?}");
        std::thread::sleep(Duration::from_millis(10));
    }
}

#[test]
fn sse_stream_matches_engine_baseline_with_exact_framing() {
    let model = model(11);
    let prompt = vec![3u32, 1, 4, 1, 5];
    let want = baseline_tokens(&model, prompt.clone(), 12);

    let server = Server::spawn(Arc::clone(&model), ServeConfig::default());
    let http = HttpServer::bind(server, NetConfig::default(), "127.0.0.1:0", None).unwrap();

    let body = r#"{"prompt":[3,1,4,1,5],"max_tokens":12,"stream":"sse"}"#;
    let reply = client::post_completions(http.addr(), None, body).unwrap();
    assert_eq!(reply.status, 200);
    assert!(reply.content_type().contains("text/event-stream"), "{}", reply.content_type());

    // raw framing: every frame is `data: <json>` + blank line, the
    // stream ends with `data: [DONE]`
    let raw = reply.read_body().unwrap();
    let frames: Vec<&str> = raw.split("\n\n").filter(|f| !f.is_empty()).collect();
    assert!(frames.len() >= 3, "started + >=1 chunk + done + [DONE]: {raw:?}");
    for f in &frames {
        assert!(f.starts_with("data: "), "bad frame {f:?}");
    }
    assert_eq!(*frames.last().unwrap(), "data: [DONE]");

    // semantic pass over the same exchange via the streaming client
    let mut reply = client::post_completions(http.addr(), None, body).unwrap();
    let out = reply.drain_stream().unwrap();
    assert!(out.started, "started frame first");
    assert_eq!(out.tokens, want, "streamed chunks reproduce the engine baseline");
    let resp = out.response.expect("done frame");
    let resp_tokens: Vec<u32> = resp.req("tokens").unwrap().as_arr().unwrap()
        .iter()
        .map(|t| t.as_f64().unwrap() as u32)
        .collect();
    assert_eq!(resp_tokens, want);
    assert_eq!(resp.req("finish_reason").unwrap().as_str(), Some("length"));
    assert_eq!(resp.req("prompt_len").unwrap().as_usize(), Some(5));

    let server = http.shutdown();
    server.shutdown();
}

#[test]
fn jsonl_and_buffered_json_modes() {
    let model = model(13);
    let want = baseline_tokens(&model, vec![7, 7, 2], 8);
    let cluster = ClusterServer::spawn(
        Arc::clone(&model),
        ClusterConfig { shards: 2, ..Default::default() },
    );
    let http = HttpServer::bind(cluster, NetConfig::default(), "127.0.0.1:0", None).unwrap();

    // JSON-lines: every line a standalone JSON object, ndjson type
    let body = r#"{"prompt":[7,7,2],"max_tokens":8,"stream":"jsonl"}"#;
    let mut reply = client::post_completions(http.addr(), None, body).unwrap();
    assert_eq!(reply.status, 200);
    assert!(reply.content_type().contains("application/x-ndjson"));
    let out = reply.drain_stream().unwrap();
    assert!(out.started);
    assert_eq!(out.tokens, want);
    assert!(out.response.is_some());

    // Accept-negotiated jsonl when "stream" is omitted
    let reply = client::request(
        http.addr(),
        "POST",
        "/v1/completions",
        &[("Accept", "application/x-ndjson")],
        Some(r#"{"prompt":[7,7,2],"max_tokens":8}"#),
    )
    .unwrap();
    assert!(reply.content_type().contains("application/x-ndjson"));
    let mut reply = reply;
    assert_eq!(reply.drain_stream().unwrap().tokens, want);

    // buffered mode: one JSON response object, content-length framed
    let body = r#"{"prompt":[7,7,2],"max_tokens":8,"stream":"json"}"#;
    let reply = client::post_completions(http.addr(), None, body).unwrap();
    assert_eq!(reply.status, 200);
    assert!(reply.content_type().contains("application/json"));
    let resp = Json::parse(&reply.read_body().unwrap()).unwrap();
    let tokens: Vec<u32> = resp.req("tokens").unwrap().as_arr().unwrap()
        .iter()
        .map(|t| t.as_f64().unwrap() as u32)
        .collect();
    assert_eq!(tokens, want);

    let cluster = http.shutdown();
    cluster.shutdown();
}

#[test]
fn malformed_requests_map_to_4xx() {
    let model = model(17);
    let cluster = ClusterServer::spawn(
        Arc::clone(&model),
        ClusterConfig {
            shards: 2,
            serve: ServeConfig { max_step_tokens: 64, ..Default::default() },
            ..Default::default()
        },
    );
    let cfg = NetConfig { max_body_bytes: 4096, ..Default::default() };
    let http = HttpServer::bind(cluster, cfg, "127.0.0.1:0", None).unwrap();
    let addr = http.addr();

    let status = |body: &str| client::post_completions(addr, None, body).unwrap().status;
    assert_eq!(status("not json"), 400);
    assert_eq!(status(r#"{"prompt":[]}"#), 400, "empty prompt");
    assert_eq!(status(r#"{"prompt":["x"]}"#), 400, "non-integer tokens");
    assert_eq!(status(r#"{"prompt":[1],"priority":"vip"}"#), 400);
    assert_eq!(status(r#"{"prompt":[1],"stream":"xml"}"#), 400);
    assert_eq!(status(r#"{"prompt":[1],"bogus":true}"#), 400, "unknown field");
    // backend validation: a prompt over max_step_tokens is rejected
    // by the cluster's submit gate and surfaces as a 400
    let huge: Vec<String> = (0..100).map(|i| i.to_string()).collect();
    let body = format!(r#"{{"prompt":[{}]}}"#, huge.join(","));
    assert_eq!(status(&body), 400, "oversized prompt");

    // routing errors
    let (s, _) = client::get(addr, "/nope").unwrap();
    assert_eq!(s, 404);
    let reply = client::request(addr, "GET", "/v1/completions", &[], None).unwrap();
    assert_eq!(reply.status, 405);
    let reply = client::request(addr, "DELETE", "/metrics", &[], None).unwrap();
    assert_eq!(reply.status, 405);

    // a body over the configured cap is refused with 413
    let big = format!(r#"{{"prompt":[{}]}}"#, vec!["1"; 4000].join(","));
    let reply = client::post_completions(addr, None, &big).unwrap();
    assert_eq!(reply.status, 413);

    // error bodies are json with a message
    let reply = client::post_completions(addr, None, "not json").unwrap();
    let err = Json::parse(&reply.read_body().unwrap()).unwrap();
    assert!(err.req("error").unwrap().req("message").unwrap().as_str().is_some());

    // none of the rejects ever reached the backend
    assert_eq!(http.stats().requests_submitted, 0);
    let cluster = http.shutdown();
    cluster.shutdown();
}

#[test]
fn disconnect_cancels_session_and_kv_drains_to_zero_bytes() {
    let model = model(19);
    let cluster = ClusterServer::spawn(
        Arc::clone(&model),
        ClusterConfig {
            shards: 2,
            serve: ServeConfig { max_new_tokens: 400, ..Default::default() },
            ..Default::default()
        },
    );
    let http = HttpServer::bind(cluster, NetConfig::default(), "127.0.0.1:0", None).unwrap();

    // a long session plus two short survivors on the other shard(s)
    let long = r#"{"prompt":[1,2,3],"max_tokens":400,"stream":"sse"}"#;
    let mut victim = client::post_completions(http.addr(), None, long).unwrap();
    assert_eq!(victim.status, 200);
    // read until it demonstrably streams, then drop the socket
    let mut chunks = 0;
    while let Some(frame) = victim.next_json().unwrap() {
        if frame.req("object").unwrap().as_str() == Some("chunk") {
            chunks += 1;
            if chunks >= 2 {
                break;
            }
        }
    }
    drop(victim); // mid-stream disconnect

    let short = r#"{"prompt":[9,9],"max_tokens":6,"stream":"jsonl"}"#;
    let mut a = client::post_completions(http.addr(), None, short).unwrap();
    let out = a.drain_stream().unwrap();
    assert_eq!(out.tokens.len(), 6, "survivors stream to completion");

    // the dropped socket must cancel its session: in-flight falls to
    // zero and the packed KV pools drain byte-exactly
    wait_drained(&http);
    assert!(http.disconnect_cancels() >= 1, "disconnect must be observed");

    let cluster = http.shutdown();
    let report = cluster.shutdown();
    for s in &report.shards {
        assert_eq!(s.final_occupancy.bytes, 0, "shard {} must drain byte-exactly", s.index);
    }
    assert_eq!(report.total_completed(), 2 + 1, "victim resolves as a completion too");
}

#[test]
fn metrics_health_and_trace_endpoints() {
    let model = model(23);
    let server = Server::spawn(Arc::clone(&model), ServeConfig::default());
    let trace = qrazor::obs::TraceBuffer::with_default_capacity();
    let cfg = NetConfig {
        tenants: parse_tenants("acme:inflight=64").unwrap(),
        ..Default::default()
    };
    let http = HttpServer::bind(server, cfg, "127.0.0.1:0", Some(trace)).unwrap();

    let body = r#"{"prompt":[5,6],"max_tokens":4,"stream":"jsonl"}"#;
    let mut r = client::post_completions(http.addr(), Some("acme"), body).unwrap();
    r.drain_stream().unwrap();
    wait_drained(&http);

    let (status, text) = client::get(http.addr(), "/metrics").unwrap();
    assert_eq!(status, 200);
    // prometheus text shape: every non-comment line is `name{labels} value`
    for line in text.lines().filter(|l| !l.is_empty() && !l.starts_with('#')) {
        let (name, value) = line.rsplit_once(' ').expect("name value");
        assert!(!name.is_empty());
        assert!(value.parse::<f64>().is_ok(), "unparseable sample {line:?}");
    }
    assert!(text.contains("qrazor_requests_submitted"), "{text}");
    assert!(text.contains("qrazor_generated_tokens"), "{text}");
    assert!(text.contains(r#"qrazor_net_requests{tenant="acme"}"#), "{text}");
    assert!(text.contains("qrazor_net_http_requests"), "{text}");

    let (status, body) = client::get(http.addr(), "/health").unwrap();
    assert_eq!(status, 200);
    let health = Json::parse(&body).unwrap();
    qrazor::obs::validate_health_json(&health).unwrap();

    let (status, body) = client::get(http.addr(), "/trace").unwrap();
    assert_eq!(status, 200);
    let trace_json = Json::parse(&body).unwrap();
    assert!(trace_json.req("traceEvents").unwrap().as_arr().is_some());

    let server = http.shutdown();
    server.shutdown();
}

#[test]
fn tenant_rate_and_quota_limits_answer_429() {
    let model = model(29);
    let server = Server::spawn(
        Arc::clone(&model),
        ServeConfig { max_new_tokens: 400, ..Default::default() },
    );
    // "free": burst of 2, negligible refill → 3rd request throttles.
    // "solo": one request in flight at a time.
    let cfg = NetConfig {
        tenants: parse_tenants("free:rps=0.001,burst=2;solo:inflight=1").unwrap(),
        ..Default::default()
    };
    let http = HttpServer::bind(server, cfg, "127.0.0.1:0", None).unwrap();
    let addr = http.addr();

    let short = r#"{"prompt":[1,2],"max_tokens":2,"stream":"jsonl"}"#;
    for _ in 0..2 {
        let mut r = client::post_completions(addr, Some("free"), short).unwrap();
        assert_eq!(r.status, 200);
        r.drain_stream().unwrap();
    }
    let reply = client::post_completions(addr, Some("free"), short).unwrap();
    assert_eq!(reply.status, 429, "rate limit");
    let err = Json::parse(&reply.read_body().unwrap()).unwrap();
    let msg = err.req("error").unwrap().req("message").unwrap().as_str().unwrap().to_string();
    assert!(msg.contains("rate"), "{msg}");

    // quota: while solo's long stream is live, a second request 429s…
    let long = r#"{"prompt":[4,4,4],"max_tokens":400,"stream":"sse"}"#;
    let mut live = client::post_completions(addr, Some("solo"), long).unwrap();
    assert_eq!(live.status, 200);
    assert!(live.next_json().unwrap().is_some(), "stream is live");
    let reply = client::post_completions(addr, Some("solo"), short).unwrap();
    assert_eq!(reply.status, 429, "inflight quota");
    let err = Json::parse(&reply.read_body().unwrap()).unwrap();
    let msg = err.req("error").unwrap().req("message").unwrap().as_str().unwrap().to_string();
    assert!(msg.contains("quota"), "{msg}");
    // …and other tenants are unaffected by solo's quota
    let mut other = client::post_completions(addr, Some("bystander"), short).unwrap();
    assert_eq!(other.status, 200);
    other.drain_stream().unwrap();
    // once the live stream resolves, solo admits again
    live.drain_stream().unwrap();
    wait_drained(&http);
    let mut again = client::post_completions(addr, Some("solo"), short).unwrap();
    assert_eq!(again.status, 200);
    again.drain_stream().unwrap();

    let counters = http.tenant_counters();
    let free = counters.iter().find(|c| c.name == "free").unwrap();
    assert_eq!(free.admitted, 2);
    assert_eq!(free.throttled_rate, 1);
    let solo = counters.iter().find(|c| c.name == "solo").unwrap();
    assert_eq!(solo.throttled_quota, 1);

    let server = http.shutdown();
    server.shutdown();
}

/// Satellite: with the engine's event ring unbounded (`event_ring =
/// 0`), the net layer's per-session byte cap is the only guard
/// against a stalled consumer — it must drop oldest `Token` events,
/// surface the count in `ServeStats::events_dropped` (and per
/// tenant), and still deliver the complete final response.
#[test]
fn slow_reader_is_capped_at_the_net_layer_and_still_resolves() {
    let model = model(31);
    let server = Server::spawn(
        Arc::clone(&model),
        ServeConfig { event_ring: 0, max_new_tokens: 64, ..Default::default() },
    );
    let cfg = NetConfig {
        // ~2 one-token events fit; the drain stalls 1.5 s so the
        // session queue provably overflows before the first pop
        session_buffer_bytes: 64,
        drain_delay_ms: 1500,
        ..Default::default()
    };
    let http = HttpServer::bind(server, cfg, "127.0.0.1:0", None).unwrap();

    let body = r#"{"prompt":[2,3,4],"max_tokens":48,"stream":"jsonl"}"#;
    let mut reply = client::post_completions(http.addr(), Some("sluggish"), body).unwrap();
    assert_eq!(reply.status, 200);
    let out = reply.drain_stream().unwrap();

    // protocol stays intact: started + done always arrive, and the
    // response carries the complete token stream…
    assert!(out.started);
    let resp = out.response.expect("done frame survives the drops");
    let resp_tokens = resp.req("tokens").unwrap().as_arr().unwrap().len();
    assert_eq!(resp_tokens, 48);
    assert_eq!(resp.req("finish_reason").unwrap().as_str(), Some("length"));
    // …while the live stream lost its oldest chunks to the byte cap
    assert!(out.tokens.len() < 48, "some chunks must have dropped");
    let dropped = http.net_events_dropped();
    assert!(dropped > 0, "drops must be counted");
    assert!(http.stats().events_dropped >= dropped, "drops surface in ServeStats");
    let counters = http.tenant_counters();
    let t = counters.iter().find(|c| c.name == "sluggish").unwrap();
    assert_eq!(t.events_dropped, dropped, "drops are attributed to the tenant");

    let server = http.shutdown();
    server.shutdown();
}

/// Submit options flow end to end: stop tokens cut generation, a
/// zero deadline expires a queued request, temperature+seed is
/// deterministic, and tenant default priorities apply.
#[test]
fn submit_options_map_through_the_wire() {
    let model = model(37);
    let server = Server::spawn(Arc::clone(&model), ServeConfig::default());
    let tenants = parse_tenants("vip:priority=interactive").unwrap();
    let cfg = NetConfig { tenants, ..Default::default() };
    let http = HttpServer::bind(server, cfg, "127.0.0.1:0", None).unwrap();
    let addr = http.addr();

    // deterministic sampled run: same seed twice → same tokens
    let sampled = r#"{"prompt":[3,5],"max_tokens":6,"temperature":0.9,"seed":42,"stream":"jsonl"}"#;
    let mut r1 = client::post_completions(addr, Some("vip"), sampled).unwrap();
    let t1 = r1.drain_stream().unwrap().tokens;
    let mut r2 = client::post_completions(addr, Some("vip"), sampled).unwrap();
    let t2 = r2.drain_stream().unwrap().tokens;
    assert_eq!(t1, t2, "seeded sampling is reproducible over the wire");
    assert_eq!(t1.len(), 6);

    // a stop token halts generation early with the right reason
    let want = baseline_tokens(&model, vec![3, 5], 6);
    let stop = want[1];
    let body =
        format!(r#"{{"prompt":[3,5],"max_tokens":6,"stop":{stop},"stream":"jsonl"}}"#);
    let mut r = client::post_completions(addr, None, &body).unwrap();
    let out = r.drain_stream().unwrap();
    let resp = out.response.unwrap();
    assert_eq!(resp.req("finish_reason").unwrap().as_str(), Some("stop_token"));
    assert!(out.tokens.len() < 6);

    // an already-expired deadline finishes as expired, zero tokens
    let body = r#"{"prompt":[8,8],"max_tokens":6,"deadline_ms":0,"stream":"jsonl"}"#;
    let mut r = client::post_completions(addr, None, body).unwrap();
    let out = r.drain_stream().unwrap();
    let resp = out.response.unwrap();
    assert_eq!(resp.req("finish_reason").unwrap().as_str(), Some("expired"));
    assert!(out.tokens.is_empty());

    let server = http.shutdown();
    server.shutdown();
}
