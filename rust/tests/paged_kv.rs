//! Acceptance suite for the paged packed-KV refactor: token streams
//! must be invariant to the page size (`kv_page_tokens = 1` reproduces
//! the pre-paging contiguous arithmetic exactly), a session admitted
//! through a copy-on-write prefix fork must stream bit-identically to
//! a cold start on the same tokens — through a bare `Engine`, the
//! threaded `Server`, a ≥2-shard cluster, and speculative decoding
//! with k ≥ 2 — and page accounting must drain to zero bytes through
//! cancel/evict churn. Needs no artifacts; runs on the nano preset.

use std::collections::BTreeMap;
use std::sync::Arc;

use qrazor::baselines::QRazor;
use qrazor::cluster::{ClusterConfig, ClusterServer, PlacementPolicy};
use qrazor::config::{ModelConfig, ServeConfig};
use qrazor::coordinator::{
    collect_sessions, Engine, FinishReason, RequestId, Sampling, ServeApi, Server, SubmitOptions,
};
use qrazor::model::quantized::{calibrate, QuantModel};
use qrazor::model::ModelWeights;
use qrazor::util::rng::Rng;

fn model(seed: u64) -> Arc<QuantModel> {
    let cfg = ModelConfig::preset("nano").unwrap();
    let w = ModelWeights::init_random(&cfg, seed);
    let mut rng = Rng::new(seed + 1);
    let seqs: Vec<Vec<u32>> = (0..2)
        .map(|_| (0..16).map(|_| rng.below(cfg.vocab as u64) as u32).collect())
        .collect();
    let cal = calibrate(&w, &seqs);
    Arc::new(QuantModel::build(&w, Box::new(QRazor::w4a4kv4(16)), &cal))
}

/// Target (W4A8 basis) + draft (packed W4A4) pair from one set of
/// weights, for the speculative axis.
fn spec_pair(seed: u64) -> (Arc<QuantModel>, Arc<QuantModel>) {
    let cfg = ModelConfig::preset("nano").unwrap();
    let w = ModelWeights::init_random(&cfg, seed);
    let mut rng = Rng::new(seed + 1);
    let seqs: Vec<Vec<u32>> = (0..2)
        .map(|_| (0..16).map(|_| rng.below(cfg.vocab as u64) as u32).collect())
        .collect();
    let cal = calibrate(&w, &seqs);
    let target = Arc::new(QuantModel::build(&w, Box::new(QRazor::w4a8kv4(16)), &cal));
    let draft = Arc::new(QuantModel::build(&w, Box::new(QRazor::w4a4kv4(16)), &cal));
    (target, draft)
}

/// Shared-prefix workload: `groups` preambles × `per_group` suffixed
/// sessions, greedy and seeded-temperature mixed — the shape the
/// prefix index exists for.
fn prefix_workload(
    seed: u64,
    groups: usize,
    per_group: usize,
    prefix_len: usize,
    vocab: u64,
) -> Vec<(Vec<u32>, usize, SubmitOptions)> {
    let mut rng = Rng::new(seed);
    let preambles: Vec<Vec<u32>> = (0..groups)
        .map(|_| (0..prefix_len).map(|_| rng.below(vocab) as u32).collect())
        .collect();
    (0..groups * per_group)
        .map(|i| {
            let mut prompt = preambles[i % groups].clone();
            let suffix = 2 + rng.index(4);
            prompt.extend((0..suffix).map(|_| rng.below(vocab) as u32));
            let mut opts = SubmitOptions::new();
            if i % 3 == 1 {
                opts = opts.sampling(Sampling::Temperature {
                    temp: 0.8,
                    seed: seed * 1000 + i as u64,
                });
            }
            (prompt, 6, opts)
        })
        .collect()
}

/// Run a workload on a bare engine and return id → (tokens, finish).
fn engine_streams(
    model: &Arc<QuantModel>,
    config: ServeConfig,
    work: &[(Vec<u32>, usize, SubmitOptions)],
) -> BTreeMap<u64, (Vec<u32>, FinishReason)> {
    let mut engine = Engine::new(Arc::clone(model), config);
    for (i, (prompt, max_new, opts)) in work.iter().enumerate() {
        engine.submit_request(opts.build(RequestId(i as u64), prompt.clone(), *max_new));
    }
    let out = engine
        .run_to_completion()
        .into_iter()
        .map(|r| (r.id.0, (r.tokens, r.finish)))
        .collect();
    assert_eq!(engine.kv_bytes(), 0, "pool must drain byte-exactly");
    out
}

#[test]
fn streams_are_invariant_to_the_page_size() {
    let m = model(31);
    let vocab = m.config.vocab as u64;
    let work = prefix_workload(5, 2, 4, 12, vocab);
    let cfg = |page: usize| ServeConfig {
        max_batch: 4,
        kv_page_tokens: page,
        ..Default::default()
    };
    // page_tokens = 1 is the pre-paging token-exact arithmetic; larger
    // pages must not change a single token
    let baseline = engine_streams(&m, cfg(1), &work);
    for page in [4usize, 16, 64] {
        let paged = engine_streams(&m, cfg(page), &work);
        assert_eq!(baseline, paged, "page size {page} changed a stream");
    }
}

#[test]
fn forked_sessions_stream_like_cold_starts_through_the_server() {
    let m = model(32);
    let vocab = m.config.vocab as u64;
    let work = prefix_workload(6, 2, 5, 16, vocab);
    // cold reference: each prompt alone in a fresh engine — no prefix
    // index entry to fork, no batching
    let mut cold = BTreeMap::new();
    for (i, (prompt, max_new, opts)) in work.iter().enumerate() {
        let one = engine_streams(
            &m,
            ServeConfig::default(),
            &[(prompt.clone(), *max_new, *opts)],
        );
        cold.insert(i as u64, one[&0].clone());
    }
    // hot path: all sessions through one threaded server, sharing
    // prefix pages copy-on-write
    let server = Server::spawn(Arc::clone(&m), ServeConfig { max_batch: 4, ..Default::default() });
    let mut ids = Vec::new();
    for (prompt, max_new, opts) in &work {
        ids.push(server.submit_with(prompt.clone(), *max_new, *opts).unwrap());
    }
    let sessions = collect_sessions(&server, work.len()).unwrap();
    for (i, id) in ids.iter().enumerate() {
        let log = &sessions[id];
        let resp = log.response.as_ref().expect("finished");
        assert_eq!(log.tokens(), resp.tokens, "streamed ≡ batch for session {i}");
        assert_eq!(
            (resp.tokens.clone(), resp.finish),
            cold[&(i as u64)],
            "session {i}: forked stream must equal its cold start"
        );
    }
    let stats = server.stats();
    assert!(stats.prefix_hits >= 1, "shared preambles must hit the index");
    assert!(stats.reused_tokens as usize >= 16, "full preamble pages reused");
    assert_eq!(stats.occupancy.bytes, 0, "sessions drained");
    server.shutdown();
}

#[test]
fn two_shard_cluster_with_prefix_affinity_stays_bit_identical() {
    let m = model(33);
    let vocab = m.config.vocab as u64;
    let work = prefix_workload(7, 2, 4, 40, vocab);
    let baseline = engine_streams(
        &m,
        ServeConfig { max_batch: 4, ..Default::default() },
        &work,
    );
    let cluster = ClusterServer::spawn(
        Arc::clone(&m),
        ClusterConfig {
            shards: 2,
            placement: PlacementPolicy::PrefixAffinity,
            ..Default::default()
        },
    );
    let mut ids = Vec::new();
    for (prompt, max_new, opts) in &work {
        ids.push(cluster.submit_with(prompt.clone(), *max_new, *opts).unwrap());
    }
    let sessions = collect_sessions(&cluster, work.len()).unwrap();
    for (i, id) in ids.iter().enumerate() {
        let resp = sessions[id].response.as_ref().expect("finished");
        assert_eq!(
            &(resp.tokens.clone(), resp.finish),
            &baseline[&(i as u64)],
            "session {i}: cluster stream diverged from the single-engine baseline"
        );
    }
    // prefix-affinity routes each preamble group to one shard, so the
    // per-shard indexes actually hit
    let stats = cluster.stats();
    assert!(stats.prefix_hits >= 2, "both preamble groups must reuse pages");
    let report = cluster.shutdown();
    for s in &report.shards {
        assert_eq!(s.final_occupancy.bytes, 0, "shard {} not drained", s.index);
    }
}

#[test]
fn speculative_decode_preserves_fork_equals_cold_at_k2() {
    let (target, draft) = spec_pair(34);
    let vocab = target.config.vocab as u64;
    let mut rng = Rng::new(35);
    let preamble: Vec<u32> = (0..20).map(|_| rng.below(vocab) as u32).collect();
    let cfg = ServeConfig { max_batch: 2, spec_k: 2, ..Default::default() };
    let mk = |suffix: &[u32]| {
        let mut p = preamble.clone();
        p.extend_from_slice(suffix);
        p
    };
    // warm engine: first session populates the prefix index (verify
    // AND draft pools), second forks both in lockstep
    let mut warm = Engine::with_draft(Arc::clone(&target), Some(Arc::clone(&draft)), cfg.clone());
    warm.submit(mk(&[7, 8]), 8, Sampling::Greedy);
    let first = warm.run_to_completion();
    assert_eq!(first.len(), 1);
    warm.submit(mk(&[9, 10, 11]), 8, Sampling::Greedy);
    let forked = warm.run_to_completion();
    assert_eq!(forked.len(), 1);
    assert!(warm.metrics.prefix_hits >= 1, "second session must fork the preamble");
    assert!(warm.metrics.spec.steps > 0, "speculation must actually run");
    // cold engine: the forked session's prompt from scratch
    let mut cold = Engine::with_draft(target, Some(draft), cfg);
    cold.submit(mk(&[9, 10, 11]), 8, Sampling::Greedy);
    let cold_out = cold.run_to_completion();
    assert_eq!(
        (&forked[0].tokens, forked[0].finish),
        (&cold_out[0].tokens, cold_out[0].finish),
        "speculative fork must match the cold speculative stream"
    );
    assert_eq!(warm.kv_bytes(), 0, "verify pool drained");
}

#[test]
fn page_accounting_drains_through_cancel_and_evict_churn() {
    let m = model(36);
    let vocab = m.config.vocab as u64;
    let work = prefix_workload(8, 1, 9, 24, vocab);
    // pool small enough to force eviction churn while sessions share
    // the preamble: 8 pages of 16 tokens
    let server = Server::spawn(
        Arc::clone(&m),
        ServeConfig {
            max_batch: 3,
            kv_pool_tokens: 128,
            kv_page_tokens: 16,
            ..Default::default()
        },
    );
    let mut ids = Vec::new();
    for (prompt, max_new, opts) in &work {
        ids.push(server.submit_with(prompt.clone(), *max_new, *opts).unwrap());
    }
    // cancel every third session immediately — some queued, some live
    for id in ids.iter().step_by(3) {
        server.cancel(*id).unwrap();
    }
    let sessions = collect_sessions(&server, work.len()).unwrap();
    let mut cancelled = 0;
    for id in &ids {
        let resp = sessions[id].response.as_ref().expect("resolved");
        if resp.finish == FinishReason::Cancelled {
            cancelled += 1;
        } else {
            assert_eq!(resp.finish, FinishReason::Length);
            assert_eq!(resp.tokens.len(), 6);
        }
    }
    assert!(cancelled >= 1, "at least the still-queued cancels must land");
    let stats = server.stats();
    assert_eq!(stats.occupancy.bytes, 0, "session bytes drain to zero");
    assert_eq!(stats.in_flight(), 0);
    assert!(
        stats.occupancy.resident_pages <= stats.occupancy.capacity_pages,
        "retained prefix snapshots stay within page capacity: {:?}",
        stats.occupancy
    );
    server.shutdown();
}
