//! Acceptance suite for the packed SDR checkpoint format
//! (`qrazor.ckpt.v1`): round-trip **bit-identity** across the policy
//! DSL presets (logits and greedy token streams, eager and cold
//! loads), byte-equality of the three writer entry points, the
//! corrupt-artifact error taxonomy, serving identity through the
//! single engine / a 2-shard cluster / the speculative draft-verify
//! pair loaded from two artifacts, zero re-quantization on load, and
//! the streaming writer's bounded-residency contract.
//!
//! The health flags and razoring counters are process-global and the
//! zero-requantization test reads them, so every test here serializes
//! on one lock — any concurrent build would pollute the counters.

use std::path::{Path, PathBuf};
use std::sync::{Arc, Mutex, MutexGuard};

use qrazor::artifact::layout::fnv1a64;
use qrazor::artifact::{
    manifest_json, write_from_checkpoint, write_model, write_quant_model, Artifact,
    ArtifactError, LoadMode,
};
use qrazor::cluster::{ClusterConfig, ClusterServer};
use qrazor::config::{ModelConfig, ServeConfig};
use qrazor::coordinator::{collect_sessions, Sampling, ServeApi, Server};
use qrazor::model::quantized::{calibrate, CalibrationData, QuantModel};
use qrazor::model::ModelWeights;
use qrazor::policy::{QuantPolicy, Site};
use qrazor::util::json::Json;
use qrazor::util::rng::Rng;

/// Every test flips or reads process-global state (health counters) or
/// hammers the thread pool; serialize the whole suite.
fn lock() -> MutexGuard<'static, ()> {
    static LOCK: Mutex<()> = Mutex::new(());
    LOCK.lock().unwrap_or_else(|e| e.into_inner())
}

fn setup(seed: u64) -> (ModelWeights, CalibrationData, Vec<Vec<u32>>) {
    let cfg = ModelConfig::preset("nano").unwrap();
    let w = ModelWeights::init_random(&cfg, seed);
    let mut rng = Rng::new(seed ^ 0x51D7);
    let seqs: Vec<Vec<u32>> = (0..3)
        .map(|_| (0..20).map(|_| rng.below(cfg.vocab as u64) as u32).collect())
        .collect();
    let cal = calibrate(&w, &seqs);
    (w, cal, seqs)
}

fn tdir() -> PathBuf {
    let d = std::env::temp_dir().join("qrazor_artifact_suite");
    std::fs::create_dir_all(&d).unwrap();
    d
}

/// The DSL presets the round-trip must hold for: uniform A4/A8 pairs
/// with and without KV4, a non-default group, a mixed per-layer
/// escalation, a per-site weight pin (down/wo stay at the 8-bit basis,
/// so the table mixes packed and fp32 records), and fp16.
const PRESETS: [&str; 8] = [
    "fp16",
    "w4a4:16",
    "w4a4kv4:16",
    "w4a8:16",
    "w4a8kv4:16",
    "w4a4kv4:32",
    "w4a4:16;layers=0:w4a8;kv=4:16",
    "w4a4kv4:16;w=down,wo:8",
];

fn argmax(v: &[f32]) -> u32 {
    let mut best = 0usize;
    for (i, &x) in v.iter().enumerate() {
        if x > v[best] {
            best = i;
        }
    }
    best as u32
}

/// Greedy decode through the incremental cache — prefill one chunk,
/// then token-by-token, exactly what the serving engine does.
fn greedy(qm: &QuantModel, prompt: &[u32], n: usize) -> Vec<u32> {
    let group = qm.policy.resolve(0, Site::KvCache).map(|p| p.group).unwrap_or(16);
    let mut cache = qm.new_cache(group);
    let logits = qm.forward_chunk(prompt, 0, &mut cache);
    let mut last = logits.row(prompt.len() - 1).to_vec();
    let mut pos = prompt.len();
    let mut out = Vec::with_capacity(n);
    for _ in 0..n {
        let tok = argmax(&last);
        out.push(tok);
        last = qm.forward_token(tok, pos, &mut cache);
        pos += 1;
    }
    out
}

fn greedy_workload(api: &impl ServeApi, vocab: u64, n: usize) -> Vec<(u64, Vec<u32>)> {
    let mut rng = Rng::new(77);
    let mut ids = Vec::new();
    for _ in 0..n {
        let len = 3 + rng.index(6);
        let prompt: Vec<u32> = (0..len).map(|_| rng.below(vocab) as u32).collect();
        ids.push(api.submit(prompt, 6, Sampling::Greedy).unwrap());
    }
    let sessions = collect_sessions(api, n).unwrap();
    ids.iter()
        .map(|id| (id.0, sessions[id].response.as_ref().unwrap().tokens.clone()))
        .collect()
}

// ---------------------------------------------------------------- //
// round trip
// ---------------------------------------------------------------- //

#[test]
fn round_trip_is_bit_identical_across_presets() {
    let _g = lock();
    let (w, cal, seqs) = setup(101);
    let tokens = &seqs[0][..10];
    for (i, dsl) in PRESETS.iter().enumerate() {
        let qm = QuantModel::build(&w, QuantPolicy::parse(dsl).unwrap(), &cal);
        let want_logits = qm.forward_full(tokens);
        let want_stream = greedy(&qm, &seqs[1][..5], 6);
        let path = tdir().join(format!("rt_{i}.qrzk"));
        write_quant_model(&path, &qm, None).unwrap();
        let art = Artifact::open(&path).unwrap();
        art.verify().unwrap();
        assert_eq!(art.header().policy.name(), qm.policy.name(), "{dsl}");
        for mode in [LoadMode::Eager, LoadMode::Cold] {
            let loaded = art.load_model(mode).unwrap();
            assert_eq!(loaded.config, qm.config, "{dsl}");
            assert_eq!(loaded.site_amax, qm.site_amax, "{dsl}: static scales must round-trip");
            assert_eq!(
                loaded.forward_full(tokens).data(),
                want_logits.data(),
                "{dsl} ({mode:?}): loaded logits diverged from the in-process build"
            );
            assert_eq!(
                greedy(&loaded, &seqs[1][..5], 6),
                want_stream,
                "{dsl} ({mode:?}): greedy stream diverged"
            );
        }
        std::fs::remove_file(&path).ok();
    }
}

// ---------------------------------------------------------------- //
// writers agree
// ---------------------------------------------------------------- //

#[test]
fn all_three_writers_produce_identical_bytes() {
    let _g = lock();
    let (w, cal, _) = setup(157);
    let policy = QuantPolicy::parse("w4a4:16;layers=0:w4a8;kv=4:16").unwrap();
    let qm = QuantModel::build(&w, policy.clone(), &cal);
    let a = tdir().join("wr_inmem.qrzk");
    let b = tdir().join("wr_model.qrzk");
    let c = tdir().join("wr_stream.qrzk");
    let ckpt = tdir().join("wr_fp.qrzc");
    write_quant_model(&a, &qm, None).unwrap();
    write_model(&b, &w, &policy, &cal, None).unwrap();
    qrazor::model::checkpoint::save_model(&ckpt, &w).unwrap();
    let stats = write_from_checkpoint(&c, &ckpt, &w.config, &policy, &cal, None, 1).unwrap();
    let bytes = std::fs::read(&a).unwrap();
    assert_eq!(bytes, std::fs::read(&b).unwrap(), "write_model diverged from write_quant_model");
    assert_eq!(bytes, std::fs::read(&c).unwrap(), "streaming writer diverged");
    // a layer-ordered checkpoint streams one layer at a time, far
    // below the whole FP model
    assert_eq!(stats.resident_layers, 1);
    let full = w.config.param_count() * 4;
    assert!(
        stats.peak_resident_bytes < full / 2,
        "peak {} must stay well under the full FP bytes {full}",
        stats.peak_resident_bytes
    );
    for p in [&a, &b, &c, &ckpt] {
        std::fs::remove_file(p).ok();
    }
}

#[test]
fn streaming_writer_enforces_the_resident_budget() {
    let _g = lock();
    let (w, cal, _) = setup(163);
    let policy = QuantPolicy::parse("w4a4kv4:16").unwrap();
    // Interleave the checkpoint: layer 0's wq arrives dead last, so
    // every later tensor must stay resident until then — budget 1
    // cannot hold that, budget 2 (all of nano's layers) can.
    let mut named = w.to_named();
    let i0 = named.iter().position(|(n, _)| n == "layers.0.wq").unwrap();
    let moved = named.remove(i0);
    named.push(moved);
    let ckpt = tdir().join("ooo_fp.qrzc");
    qrazor::model::checkpoint::save_named(&ckpt, &named).unwrap();
    let out = tdir().join("ooo.qrzk");
    let err = write_from_checkpoint(&out, &ckpt, &w.config, &policy, &cal, None, 1)
        .unwrap_err()
        .to_string();
    assert!(err.contains("resident-layers"), "unexpected error: {err}");
    let stats = write_from_checkpoint(&out, &ckpt, &w.config, &policy, &cal, None, 2).unwrap();
    assert_eq!(stats.resident_layers, 2);
    // the artifact is canonical regardless of arrival order
    let reference = tdir().join("ooo_ref.qrzk");
    write_model(&reference, &w, &policy, &cal, None).unwrap();
    assert_eq!(std::fs::read(&out).unwrap(), std::fs::read(&reference).unwrap());
    for p in [&ckpt, &out, &reference] {
        std::fs::remove_file(p).ok();
    }
}

// ---------------------------------------------------------------- //
// one manifest builder
// ---------------------------------------------------------------- //

#[test]
fn manifest_builder_reproduces_legacy_cli_bytes() {
    let _g = lock();
    let policy = QuantPolicy::parse("w4a4:16;layers=0:w4a8;kv=4:16").unwrap();
    qrazor::obs::health_reset();
    let health = qrazor::obs::health_json(None);
    // The pre-artifact CLI built `quantize --manifest-out` exactly so;
    // the shared builder must reproduce it byte for byte.
    let legacy =
        Json::from_pairs(vec![("policy", policy.to_json()), ("health", health.clone())]);
    assert_eq!(manifest_json(&policy, Some(health)).to_string(), legacy.to_string());
    let bare = manifest_json(&policy, None);
    assert_eq!(bare.get("policy").unwrap().to_string(), policy.to_json().to_string());
    assert!(bare.get("health").is_none());
}

// ---------------------------------------------------------------- //
// corruption taxonomy
// ---------------------------------------------------------------- //

fn header_span(bytes: &[u8]) -> (usize, usize) {
    let off = u64::from_le_bytes(bytes[16..24].try_into().unwrap()) as usize;
    let len = u64::from_le_bytes(bytes[24..32].try_into().unwrap()) as usize;
    (off, len)
}

/// Rewrite the trailing header JSON through `f`, re-patching the
/// preamble's length and checksum — so only the *content* disagrees,
/// never the framing.
fn rewrite_header(path: &Path, f: &dyn Fn(&str) -> String) {
    let mut bytes = std::fs::read(path).unwrap();
    let (off, len) = header_span(&bytes);
    let new = f(std::str::from_utf8(&bytes[off..off + len]).unwrap());
    bytes.truncate(off);
    bytes.extend_from_slice(new.as_bytes());
    bytes[24..32].copy_from_slice(&(new.len() as u64).to_le_bytes());
    bytes[32..40].copy_from_slice(&fnv1a64(new.as_bytes()).to_le_bytes());
    std::fs::write(path, bytes).unwrap();
}

#[test]
fn corrupt_artifacts_name_their_failure() {
    let _g = lock();
    let (w, cal, _) = setup(131);
    let qm = QuantModel::build(&w, QuantPolicy::parse("w4a4kv4:16").unwrap(), &cal);
    let good = tdir().join("taxonomy.qrzk");
    write_quant_model(&good, &qm, None).unwrap();
    let bytes = std::fs::read(&good).unwrap();
    let (h_off, h_len) = header_span(&bytes);

    let open_mutated = |name: &str, mutate: &dyn Fn(&mut Vec<u8>)| -> ArtifactError {
        let p = tdir().join(name);
        let mut b = bytes.clone();
        mutate(&mut b);
        std::fs::write(&p, &b).unwrap();
        let e = Artifact::open(&p).err().expect("corruption must not open cleanly");
        std::fs::remove_file(&p).ok();
        e
    };

    // missing file
    let missing = Artifact::open(Path::new("/nonexistent/qrazor.qrzk"));
    assert!(matches!(missing, Err(ArtifactError::Io(_))));
    // shorter than the preamble
    let e = open_mutated("tx_short.qrzk", &|b| b.truncate(40));
    assert!(matches!(e, ArtifactError::Truncated { .. }), "{e}");
    // wrong magic
    let e = open_mutated("tx_magic.qrzk", &|b| b[0] ^= 0xff);
    assert!(matches!(e, ArtifactError::BadMagic { .. }), "{e}");
    // future version
    let e = open_mutated("tx_version.qrzk", &|b| {
        b[8..12].copy_from_slice(&99u32.to_le_bytes())
    });
    assert!(matches!(e, ArtifactError::BadVersion { found: 99, supported: 1 }), "{e}");
    // file ends inside the header
    let e = open_mutated("tx_trunc.qrzk", &|b| b.truncate(h_off + h_len - 3));
    assert!(matches!(e, ArtifactError::Truncated { .. }), "{e}");
    // header bytes flipped after writing
    let e = open_mutated("tx_hsum.qrzk", &|b| b[h_off] ^= 0x01);
    assert!(matches!(e, ArtifactError::HeaderChecksum { .. }), "{e}");

    // a flipped payload byte: opens (structure is intact), fails
    // verify/eager-load with the tensor and plane named, still loads
    // cold (payload validation is deferred by design)
    let p = tdir().join("tx_section.qrzk");
    let mut b = bytes.clone();
    b[64] ^= 0x01;
    std::fs::write(&p, &b).unwrap();
    let art = Artifact::open(&p).unwrap();
    match art.verify() {
        Err(ArtifactError::SectionChecksum { tensor, plane, .. }) => {
            assert_eq!(tensor, "embed");
            assert_eq!(plane, "data");
        }
        other => panic!("expected SectionChecksum, got {other:?}"),
    }
    assert!(matches!(
        art.load_model(LoadMode::Eager),
        Err(ArtifactError::SectionChecksum { .. })
    ));
    assert!(art.load_model(LoadMode::Cold).is_ok(), "cold load defers payload checksums");
    std::fs::remove_file(&p).ok();

    // header edits that keep the checksum valid but contradict the
    // table: wrong schema, scheme-backed policy, tampered dims/specs
    let tamper = |name: &str, f: &dyn Fn(&str) -> String| -> ArtifactError {
        let p = tdir().join(name);
        std::fs::copy(&good, &p).unwrap();
        rewrite_header(&p, f);
        let e = Artifact::open(&p).err().expect("tampered header must not open");
        std::fs::remove_file(&p).ok();
        e
    };
    let e = tamper("tx_schema.qrzk", &|h| h.replacen("qrazor.ckpt.v1", "qrazor.ckpt.v9", 1));
    assert!(matches!(e, ArtifactError::BadHeader { .. }), "{e}");
    let e = tamper("tx_scheme.qrzk", &|h| {
        let pat = "\"kind\": \"razor\"";
        assert!(h.contains(pat), "no policy kind in header");
        h.replacen(pat, "\"kind\": \"scheme\"", 1)
    });
    assert!(matches!(e, ArtifactError::PolicyIncompatible { .. }), "{e}");
    let e = tamper("tx_rows.qrzk", &|h| {
        let pat = "\"rows\": 64";
        assert!(h.contains(pat), "no packed record in header");
        h.replacen(pat, "\"rows\": 63", 1)
    });
    assert!(matches!(e, ArtifactError::TableMismatch { .. }), "{e}");
    let e = tamper("tx_spec.qrzk", &|h| {
        let pat = "\"spec\": {\"basis\": 8,\"group\": 16,\"target\": 4}";
        assert!(h.contains(pat), "no weight spec in header");
        h.replacen(pat, "\"spec\": {\"basis\": 8,\"group\": 16,\"target\": 8}", 1)
    });
    assert!(matches!(e, ArtifactError::TableMismatch { .. }), "{e}");

    std::fs::remove_file(&good).ok();
}

// ---------------------------------------------------------------- //
// serving identity
// ---------------------------------------------------------------- //

#[test]
fn serving_from_artifact_is_stream_identical() {
    let _g = lock();
    let (w, cal, _) = setup(211);
    let dsl = "w4a4kv4:16;layers=0:w4a8";
    let qm = QuantModel::build(&w, QuantPolicy::parse(dsl).unwrap(), &cal);
    let vocab = w.config.vocab as u64;
    let path = tdir().join("serve.qrzk");
    write_quant_model(&path, &qm, None).unwrap();
    let serve_cfg = ServeConfig { max_new_tokens: 8, policy: dsl.into(), ..Default::default() };

    let server = Server::spawn(qm, serve_cfg.clone());
    let want = greedy_workload(&server, vocab, 6);
    server.shutdown();

    // single engine, eager and cold
    for mode in [LoadMode::Eager, LoadMode::Cold] {
        let loaded = Server::spawn_from_artifact(&path, mode, serve_cfg.clone()).unwrap();
        let got = greedy_workload(&loaded, vocab, 6);
        loaded.shutdown();
        assert_eq!(want, got, "{mode:?}: loaded engine streams diverged");
    }

    // 2-shard cluster from the same artifact, across KV page sizes —
    // the streams must not depend on pages, shards, or the load path
    for pages in [1usize, 8] {
        let cfg = ServeConfig { kv_page_tokens: pages, ..serve_cfg.clone() };
        let cluster = ClusterServer::spawn_from_artifact(
            &path,
            LoadMode::Eager,
            ClusterConfig { shards: 2, serve: cfg, ..Default::default() },
        )
        .unwrap();
        let got = greedy_workload(&cluster, vocab, 6);
        cluster.shutdown();
        assert_eq!(want, got, "page size {pages}: cluster streams diverged");
    }

    // one mapping feeds every consumer: loading clones the Arc into
    // the packed planes instead of copying them
    let art = Artifact::open(&path).unwrap();
    let before = Arc::strong_count(art.map());
    let loaded = art.load_model(LoadMode::Eager).unwrap();
    assert!(
        Arc::strong_count(art.map()) > before,
        "loaded planes must share the artifact's mapping"
    );
    drop(loaded);
    assert_eq!(Arc::strong_count(art.map()), before);
    std::fs::remove_file(&path).ok();
}

#[test]
fn speculative_pair_from_two_artifacts_matches_plain_decode() {
    let _g = lock();
    let (w, cal, _) = setup(223);
    let target = QuantModel::build(&w, QuantPolicy::parse("w4a8kv4:16").unwrap(), &cal);
    let draft = QuantModel::build(&w, QuantPolicy::parse("w4a4kv4:16").unwrap(), &cal);
    let tp = tdir().join("spec_target.qrzk");
    let dp = tdir().join("spec_draft.qrzk");
    write_quant_model(&tp, &target, None).unwrap();
    write_quant_model(&dp, &draft, None).unwrap();
    let vocab = w.config.vocab as u64;
    let base_cfg = ServeConfig {
        max_new_tokens: 8,
        policy: "w4a8kv4:16".into(),
        draft_policy: "w4a4kv4:16".into(),
        ..Default::default()
    };

    let plain = Server::spawn_from_artifact(&tp, LoadMode::Eager, base_cfg.clone()).unwrap();
    let want = greedy_workload(&plain, vocab, 6);
    plain.shutdown();

    let t_qm = Artifact::open(&tp).unwrap().load_model(LoadMode::Eager).unwrap();
    let d_qm = Artifact::open(&dp).unwrap().load_model(LoadMode::Eager).unwrap();
    let spec = Server::spawn_with_draft(
        t_qm,
        Some(Arc::new(d_qm)),
        ServeConfig { spec_k: 2, ..base_cfg },
    );
    let got = greedy_workload(&spec, vocab, 6);
    let stats = spec.stats();
    spec.shutdown();
    assert_eq!(want, got, "speculative streams from two artifacts must match plain decode");
    assert!(stats.spec.steps > 0, "speculative rounds must actually run");
    for p in [&tp, &dp] {
        std::fs::remove_file(p).ok();
    }
}

// ---------------------------------------------------------------- //
// zero re-quantization
// ---------------------------------------------------------------- //

#[test]
fn loading_runs_zero_requantization() {
    let _g = lock();
    let (w, cal, seqs) = setup(227);
    let qm = QuantModel::build(&w, QuantPolicy::parse("w4a4kv4:16").unwrap(), &cal);
    let path = tdir().join("zero_requant.qrzk");
    write_quant_model(&path, &qm, None).unwrap();
    drop(qm);
    qrazor::obs::health_reset();
    qrazor::obs::set_health(true);
    let art = Artifact::open(&path).unwrap();
    let loaded = art.load_model(LoadMode::Eager).unwrap();
    assert_eq!(
        qrazor::obs::razored_groups_total(),
        0,
        "open + verify + load must not razor a single group"
    );
    let _ = loaded.forward_full(&seqs[0][..8]);
    assert!(
        qrazor::obs::razored_groups_total() > 0,
        "the counter is live: a forward razors activations"
    );
    qrazor::obs::set_health(false);
    qrazor::obs::health_reset();
    std::fs::remove_file(&path).ok();
}
