//! The acceptance suite for the per-site policy redesign: uniform
//! policies must be **bit-identical** to the pre-redesign
//! `Box<dyn Scheme>` path, for every baseline and every QRazor variant
//! — packed GEMM and packed KV attention included — and mixed
//! per-layer policies must run end-to-end through serving (single
//! engine and ≥2-shard cluster, plain and speculative).
//!
//! Two independent implementations are compared:
//! * `ref_forward_full` below re-implements the pre-redesign
//!   scheme-hook forward verbatim (prep per site, static scales at
//!   the old fixed 16/8 basis bits, `scheme.kv` on Q/K/V, packed
//!   linears exactly where `prep_linear` attached them);
//! * `QuantModel::build` runs the new policy-resolved forward —
//!   through the **uniform scheme backend** when given a
//!   `Box<dyn Scheme>` and through the **razor-native resolution**
//!   when given a DSL policy.
//! All three must agree to the bit.

use std::sync::Arc;

use qrazor::baselines::{
    awq::AwqScheme, qllm::QllmScheme, qserve::QServeScheme, quarot::QuaRotScheme, rtn::RtnScheme,
    smoothquant::SmoothQuantScheme, Fp16, PreparedLinear, QRazor, Scheme,
};
use qrazor::config::{ModelConfig, ServeConfig};
use qrazor::coordinator::{ServeApi, Server};
use qrazor::cluster::{ClusterConfig, ClusterServer};
use qrazor::model::quantized::{calibrate, CalibrationData, DecodeCache, QuantModel};
use qrazor::model::{apply_rope, causal_attention, ModelWeights};
use qrazor::policy::QuantPolicy;
use qrazor::tensor::{add_assign, rmsnorm, silu, Tensor};
use qrazor::util::rng::Rng;

fn setup(seed: u64) -> (ModelWeights, CalibrationData, Vec<Vec<u32>>) {
    let cfg = ModelConfig::preset("nano").unwrap();
    let w = ModelWeights::init_random(&cfg, seed);
    let mut rng = Rng::new(seed ^ 0x9E37);
    let seqs: Vec<Vec<u32>> = (0..3)
        .map(|_| (0..20).map(|_| rng.below(cfg.vocab as u64) as u32).collect())
        .collect();
    let cal = calibrate(&w, &seqs);
    (w, cal, seqs)
}

/// The pre-redesign forward, reproduced hook-for-hook: this is what
/// `QuantModel::forward_full` did when the model held one
/// `Box<dyn Scheme>` (fixed 16-bit activation basis, 8-bit KV basis,
/// `scheme.kv` on Q/K/V, packed linears wherever `prep_linear`
/// attached them).
fn ref_forward_full(
    w: &ModelWeights,
    scheme: &dyn Scheme,
    cal: &CalibrationData,
    tokens: &[u32],
) -> Tensor<f32> {
    let cfg = &w.config;
    let (d, hd) = (cfg.dim, cfg.head_dim());
    let t = tokens.len();
    let scale = |site: &str, bits: u32| -> Option<f32> {
        cal.calibrator
            .amax(site)
            .map(|amax| qrazor::quant::absmax_scale_from_amax(amax, bits))
    };
    let prep = |weight: &Tensor<f32>, site: &str| scheme.prep_linear(weight, cal.sample(site));
    let fwd = |pl: &PreparedLinear, x: &Tensor<f32>, s: Option<f32>| pl.forward(x, s, scheme);
    let mut x = Tensor::zeros(&[t, d]);
    for (i, &tok) in tokens.iter().enumerate() {
        x.row_mut(i).copy_from_slice(w.embed.row(tok as usize));
    }
    let mut normed = Tensor::zeros(&[t, d]);
    for (li, layer) in w.layers.iter().enumerate() {
        for i in 0..t {
            rmsnorm(x.row(i), &layer.attn_norm, 1e-5, normed.row_mut(i));
        }
        let s_in = scale(&format!("l{li}.attn_in"), 16);
        let wq = prep(&layer.wq, &format!("l{li}.attn_in"));
        let wk = prep(&layer.wk, &format!("l{li}.attn_in"));
        let wv = prep(&layer.wv, &format!("l{li}.attn_in"));
        let mut q = fwd(&wq, &normed, s_in);
        let mut k = fwd(&wk, &normed, s_in);
        let v = fwd(&wv, &normed, s_in);
        apply_rope(&mut q, cfg.heads, hd, 0);
        apply_rope(&mut k, cfg.kv_heads, hd, 0);
        let qq = scheme.kv(&q, scale(&format!("l{li}.q"), 8));
        let kq = scheme.kv(&k, scale(&format!("l{li}.k"), 8));
        let vq = scheme.kv(&v, scale(&format!("l{li}.v"), 8));
        let ctx = causal_attention(&qq, &kq, &vq, cfg.heads, cfg.kv_heads, hd);
        let wo = prep(&layer.wo, &format!("l{li}.attn_out"));
        let attn_out = fwd(&wo, &ctx, scale(&format!("l{li}.attn_out"), 16));
        add_assign(&mut x, &attn_out);
        for i in 0..t {
            rmsnorm(x.row(i), &layer.ffn_norm, 1e-5, normed.row_mut(i));
        }
        let s_ffn = scale(&format!("l{li}.ffn_in"), 16);
        let w_gate = prep(&layer.w_gate, &format!("l{li}.ffn_in"));
        let w_up = prep(&layer.w_up, &format!("l{li}.ffn_in"));
        let gate = fwd(&w_gate, &normed, s_ffn);
        let up = fwd(&w_up, &normed, s_ffn);
        let mut h = Tensor::zeros(&[t, cfg.ffn_hidden]);
        for ((o, &g), &u) in h.data_mut().iter_mut().zip(gate.data()).zip(up.data()) {
            *o = silu(g) * u;
        }
        let w_down = prep(&layer.w_down, &format!("l{li}.ffn_down_in"));
        let ffn_out = fwd(&w_down, &h, scale(&format!("l{li}.ffn_down_in"), 16));
        add_assign(&mut x, &ffn_out);
    }
    for i in 0..t {
        rmsnorm(x.row(i), &w.final_norm, 1e-5, normed.row_mut(i));
    }
    let head = prep(&w.lm_head, "lm_head_in");
    fwd(&head, &normed, scale("lm_head_in", 16))
}

/// Every scheme the repo ships, as fresh boxed instances.
fn all_schemes() -> Vec<(&'static str, Box<dyn Scheme>)> {
    vec![
        ("fp16", Box::new(Fp16)),
        ("qrazor-w4a4", Box::new(QRazor::w4a4(16))),
        ("qrazor-w4a4kv4", Box::new(QRazor::w4a4kv4(16))),
        ("qrazor-w4a8", Box::new(QRazor::w4a8(16))),
        ("qrazor-w4a8kv4", Box::new(QRazor::w4a8kv4(16))),
        ("qrazor-abl-w8a8", Box::new(QRazor::ablation(8, 8, 8))),
        ("qrazor-abl-w4a16", Box::new(QRazor::ablation(4, 16, 16))),
        ("rtn-w4a4", Box::new(RtnScheme::w4a4(16))),
        ("rtn-w4a4kv4", Box::new(RtnScheme::w4a4kv4(16))),
        ("smoothquant-w4a4", Box::new(SmoothQuantScheme::w4a4(0.5))),
        ("quarot-rtn", Box::new(QuaRotScheme::rtn_w4a4kv4())),
        ("quarot-gptq", Box::new(QuaRotScheme::gptq_w4a4kv4())),
        ("awq-w4a4", Box::new(AwqScheme::w4a4(16))),
        ("qllm-w4a4", Box::new(QllmScheme::w4a4())),
        ("qserve-w4a8kv4", Box::new(QServeScheme::w4a8kv4(16))),
    ]
}

#[test]
fn uniform_scheme_policies_match_the_pre_redesign_forward_bit_exactly() {
    // Every baseline and QRazor variant: building through the policy
    // layer (uniform scheme backend) must reproduce the pre-redesign
    // scheme-hook forward to the bit, packed GEMMs included.
    let (w, cal, seqs) = setup(11);
    let tokens = &seqs[0][..12];
    for (name, scheme) in all_schemes() {
        let want = ref_forward_full(&w, scheme.as_ref(), &cal, tokens);
        let qm = QuantModel::build(&w, scheme, &cal);
        let got = qm.forward_full(tokens);
        assert_eq!(
            got.data(),
            want.data(),
            "{name}: policy-built forward diverged from the scheme-hook reference"
        );
    }
}

/// The DSL strings whose razor-native resolution must be bit-identical
/// to the equivalent scheme-backed uniform policy.
fn qrazor_pairs() -> Vec<(&'static str, Box<dyn Scheme>)> {
    vec![
        ("fp16", Box::new(Fp16)),
        ("w4a4:16", Box::new(QRazor::w4a4(16))),
        ("w4a4kv4:16", Box::new(QRazor::w4a4kv4(16))),
        ("w4a8:16", Box::new(QRazor::w4a8(16))),
        ("w4a8kv4:16", Box::new(QRazor::w4a8kv4(16))),
        ("w4a4kv4:32", Box::new(QRazor::w4a4kv4(32))),
        ("w8a8:8", Box::new(QRazor::ablation(8, 8, 8))),
        ("w4a16:16", Box::new(QRazor::ablation(4, 16, 16))),
    ]
}

#[test]
fn razor_native_policies_match_scheme_backed_uniform_bit_exactly() {
    // The same preset through two genuinely different resolution
    // paths: razor-native (parsed DSL) vs the scheme's own hooks
    // (uniform backend). Full-forward logits must agree to the bit.
    let (w, cal, seqs) = setup(23);
    let tokens = &seqs[1][..12];
    for (dsl, scheme) in qrazor_pairs() {
        let via_scheme = QuantModel::build(&w, scheme, &cal);
        let via_policy = QuantModel::build(&w, QuantPolicy::parse(dsl).unwrap(), &cal);
        let a = via_scheme.forward_full(tokens);
        let b = via_policy.forward_full(tokens);
        assert_eq!(a.data(), b.data(), "{dsl}: razor-native ≠ scheme-backed");
        assert_eq!(
            via_scheme.weight_operand_bytes(),
            via_policy.weight_operand_bytes(),
            "{dsl}: packed operand accounting diverged"
        );
    }
}

#[test]
fn razor_native_decode_matches_scheme_backed_incl_packed_kv_attention() {
    // Incremental decode — packed KV caches, decompression-free
    // attention, chunked prefill — through both backends: logits and
    // cache bytes must be identical at every step.
    let (w, cal, seqs) = setup(31);
    let tokens = &seqs[2][..10];
    for (dsl, scheme) in qrazor_pairs() {
        let via_scheme = QuantModel::build(&w, scheme, &cal);
        let via_policy = QuantModel::build(&w, QuantPolicy::parse(dsl).unwrap(), &cal);
        let group = via_policy
            .policy
            .resolve(0, qrazor::policy::Site::KvCache)
            .map(|p| p.group)
            .unwrap_or(16);
        let mut ca = via_scheme.new_cache(group);
        let mut cb = via_policy.new_cache(group);
        assert_eq!(
            matches!(ca, DecodeCache::Sdr(_)),
            matches!(cb, DecodeCache::Sdr(_)),
            "{dsl}: cache kind diverged"
        );
        // prefill as one chunk, then token-by-token decode
        let split = tokens.len() / 2;
        let a0 = via_scheme.forward_chunk(&tokens[..split], 0, &mut ca);
        let b0 = via_policy.forward_chunk(&tokens[..split], 0, &mut cb);
        assert_eq!(a0.data(), b0.data(), "{dsl}: prefill chunk diverged");
        for (i, &tok) in tokens[split..].iter().enumerate() {
            let pos = split + i;
            let a = via_scheme.forward_token(tok, pos, &mut ca);
            let b = via_policy.forward_token(tok, pos, &mut cb);
            assert_eq!(a, b, "{dsl}: decode diverged at pos {pos}");
            assert_eq!(ca.bytes(), cb.bytes(), "{dsl}: cache bytes diverged at pos {pos}");
        }
    }
}

#[test]
fn prop_equivalence_over_random_models() {
    // Property form over random weights/prompts: razor-native ≡
    // scheme-backed for the full QRazor family, exact to the bit.
    for seed in [101u64, 202, 303, 404] {
        let (w, cal, seqs) = setup(seed);
        let tokens = &seqs[0][..8];
        for (dsl, scheme) in [
            ("w4a4kv4:16", Box::new(QRazor::w4a4kv4(16)) as Box<dyn Scheme>),
            ("w4a8kv4:16", Box::new(QRazor::w4a8kv4(16)) as Box<dyn Scheme>),
        ] {
            let a = QuantModel::build(&w, scheme, &cal).forward_full(tokens);
            let b = QuantModel::build(&w, QuantPolicy::parse(dsl).unwrap(), &cal)
                .forward_full(tokens);
            assert_eq!(a.data(), b.data(), "seed {seed}: {dsl}");
        }
    }
}

#[test]
fn mixed_policy_escalation_strictly_reduces_calibration_error() {
    // The sensitivity builder's contract on nano: escalating the
    // top-k most error-sensitive layers from A4 to A8 strictly
    // reduces the activation razoring error over the calibration
    // samples (and only touches the chosen layers).
    let (w, cal, _) = setup(47);
    let layers = w.config.layers;
    let uniform = QuantPolicy::parse("w4a4kv4:16").unwrap();
    let base_err = uniform.act_calibration_error(&cal, layers);
    assert!(base_err > 0.0, "A4 razoring must have measurable error");
    let mut prev = base_err;
    for k in 1..=layers {
        let esc = uniform.sensitivity_escalate(&cal, layers, k).unwrap();
        let err = esc.act_calibration_error(&cal, layers);
        assert!(
            err < prev,
            "top-{k} escalation must strictly reduce calib error ({err} vs {prev})"
        );
        prev = err;
        // exactly k layers escalated to A8, the rest untouched
        let escalated = (0..layers)
            .filter(|&li| {
                esc.resolve(li, qrazor::policy::Site::Act).unwrap().target_bits == Some(8)
            })
            .count();
        assert_eq!(escalated, k);
        // weights stay razored W4 everywhere
        for li in 0..layers {
            assert_eq!(
                esc.resolve(li, qrazor::policy::Site::Wq).unwrap().target_bits,
                Some(4),
                "escalation must not touch weight plans"
            );
        }
    }
}

#[test]
fn mixed_policy_forward_error_sits_between_uniform_a4_and_a8() {
    // End-to-end sanity on the nano model: per-layer W4A8 escalation
    // lands between uniform W4A4 (noisier) and uniform W4A8 (cleaner)
    // against the FP reference.
    let (w, cal, seqs) = setup(59);
    let tokens = &seqs[0][..12];
    let fp = qrazor::model::forward_full(&w, tokens);
    let err = |dsl: &str| {
        let qm = QuantModel::build(&w, QuantPolicy::parse(dsl).unwrap(), &cal);
        qrazor::baselines::rel_error(&fp, &qm.forward_full(tokens))
    };
    let e_a4 = err("w4a4kv4:16");
    let e_mixed = err("w4a4kv4:16;layers=0:w4a8");
    let e_a8 = err("w4a8kv4:16");
    assert!(e_a8 < e_a4, "a8 {e_a8} vs a4 {e_a4}");
    assert!(e_mixed < e_a4, "escalating a layer must reduce forward error: {e_mixed} vs {e_a4}");
}

fn greedy_workload(api: &impl ServeApi, vocab: u64, n: usize) -> Vec<(u64, Vec<u32>)> {
    let mut rng = Rng::new(77);
    let mut ids = Vec::new();
    for _ in 0..n {
        let len = 3 + rng.index(6);
        let prompt: Vec<u32> = (0..len).map(|_| rng.below(vocab) as u32).collect();
        ids.push(api.submit(prompt, 6, qrazor::coordinator::Sampling::Greedy).unwrap());
    }
    let sessions = qrazor::coordinator::collect_sessions(api, n).unwrap();
    ids.iter()
        .map(|id| (id.0, sessions[id].response.as_ref().unwrap().tokens.clone()))
        .collect()
}

#[test]
fn mixed_policy_serves_end_to_end_single_engine_and_cluster() {
    // A per-layer W4A4/W4A8 mixed policy (with KV4) runs through the
    // full serving stack: single-engine Server, a 2-shard cluster,
    // and the speculative draft/verify pair expressed as two named
    // policies — all producing identical greedy streams.
    let (w, cal, _) = setup(83);
    let vocab = w.config.vocab as u64;
    let dsl = "w4a4kv4:16;layers=0:w4a8";
    let build = || Arc::new(QuantModel::build(&w, QuantPolicy::parse(dsl).unwrap(), &cal));
    let serve_cfg = ServeConfig {
        max_new_tokens: 8,
        policy: dsl.into(),
        draft_policy: "w4a4kv4:16".into(),
        ..Default::default()
    };

    let server = Server::spawn(build(), serve_cfg.clone());
    let want = greedy_workload(&server, vocab, 6);
    server.shutdown();

    let cluster = ClusterServer::spawn(
        build(),
        ClusterConfig { shards: 2, serve: serve_cfg.clone(), ..Default::default() },
    );
    let got = greedy_workload(&cluster, vocab, 6);
    cluster.shutdown();
    assert_eq!(want, got, "cluster streams must match the single engine");

    // speculative: draft = uniform packed W4A4, verify = the mixed
    // policy — the ServeConfig names the pair; streams stay identical
    let draft = Arc::new(QuantModel::build(
        &w,
        QuantPolicy::parse(&serve_cfg.draft_policy).unwrap(),
        &cal,
    ));
    let spec_cfg = ServeConfig { spec_k: 2, ..serve_cfg.clone() };
    let spec_server = Server::spawn_with_draft(build(), Some(Arc::clone(&draft)), spec_cfg);
    let spec_got = greedy_workload(&spec_server, vocab, 6);
    let stats = spec_server.stats();
    spec_server.shutdown();
    assert_eq!(want, spec_got, "speculative streams must match plain decode");
    assert!(stats.spec.steps > 0, "speculative rounds must actually run");

    // and the same pair across a 2-shard cluster
    let spec_cluster = ClusterServer::spawn_with_draft(
        build(),
        Some(draft),
        ClusterConfig {
            shards: 2,
            serve: ServeConfig { spec_k: 2, ..serve_cfg },
            ..Default::default()
        },
    );
    let spec_cluster_got = greedy_workload(&spec_cluster, vocab, 6);
    spec_cluster.shutdown();
    assert_eq!(want, spec_cluster_got, "speculative cluster streams must match");
}

#[test]
fn eval_policy_sweep_smoke_on_nano() {
    // The `eval --policy` path at the harness level: sweep a uniform
    // and a mixed policy through Experiment::eval_policies and render
    // the Table-2-style accuracy/footprint report. (The CLI drives
    // exactly this code; CI has no trained artifacts, so the smoke
    // builds its experiment from random weights.)
    use qrazor::data::corpus::{pack_sequences, split_corpus, wiki_corpus};
    use qrazor::data::tokenizer::Tokenizer;
    use qrazor::eval::build_suite;
    use qrazor::eval::harness::{render_policy_table, EvalScale, Experiment};
    let cfg = ModelConfig::preset("nano").unwrap();
    let w = ModelWeights::init_random(&cfg, 5);
    let world = wiki_corpus(20_000, 9);
    let (train_text, eval_text) = split_corpus(&world, 0.2);
    let tokenizer = Tokenizer::train(&train_text[..train_text.len().min(10_000)], cfg.vocab);
    let eval_tokens = tokenizer.encode(&eval_text);
    let seqs: Vec<Vec<u32>> = pack_sequences(&eval_tokens, 32).into_iter().take(4).collect();
    assert!(!seqs.is_empty());
    let calib_tokens = tokenizer.encode(&train_text[..train_text.len().min(10_000)]);
    let calib: Vec<Vec<u32>> = pack_sequences(&calib_tokens, 32).into_iter().take(4).collect();
    let cal = calibrate(&w, &calib);
    let tasks = build_suite(&eval_text, &tokenizer, 4, 9, 11);
    let exp = Experiment {
        config: cfg,
        weights: w,
        cal,
        tokenizer,
        wiki_seqs: seqs.clone(),
        lambada_seqs: seqs,
        tasks,
        scale: EvalScale::quick(),
    };
    let rows = exp.eval_policies(vec![
        QuantPolicy::parse("w4a4kv4:16").unwrap(),
        QuantPolicy::parse("w4a4:16;layers=0:w4a8;kv=4:16").unwrap(),
    ]);
    assert_eq!(rows.len(), 2);
    for r in &rows {
        assert!(r.result.ppl_wiki.is_finite() && r.result.ppl_wiki > 0.0, "{}", r.result.name);
        assert!((4.0..5.0).contains(&r.kv_effective_bits), "{}", r.kv_effective_bits);
        assert!((0.45..=0.55).contains(&r.weight_ratio()), "{}", r.weight_ratio());
    }
    let table = render_policy_table("policy sweep (nano)", &rows);
    assert!(table.contains("w4a4kv4:16"));
    assert!(table.contains("layers=0"));
    assert!(table.contains("KV-bits"));
}

#[test]
fn mixed_policy_packs_per_layer_operands() {
    // Layer 0 escalated to A8 must still carry a packed weight (the
    // byte-coded GEMM pairs with it); the A4 layers carry the nibble
    // pairing. Operand bytes stay at the packed ratio either way.
    let (w, cal, seqs) = setup(91);
    let qm = QuantModel::build(
        &w,
        QuantPolicy::parse("w4a4kv4:16;layers=0:w4a8").unwrap(),
        &cal,
    );
    let (packed, unpacked) = qm.weight_operand_bytes();
    let ratio = packed as f64 / unpacked as f64;
    assert!((0.45..=0.55).contains(&ratio), "ratio {ratio}");
    // decode works end to end on the packed path
    let mut cache = qm.new_cache(16);
    assert!(matches!(cache, DecodeCache::Sdr(_)));
    let logits = qm.forward_chunk(&seqs[0][..6], 0, &mut cache);
    assert!(logits.data().iter().all(|v| v.is_finite()));
}
