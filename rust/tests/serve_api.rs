//! Acceptance suite for the unified streaming serving API: streaming ≡
//! batch equivalence (engine and cluster, greedy and sampled, with and
//! without speculation), byte-exact cancellation accounting, priority
//! ordering, and deadline expiry — all through the same [`ServeApi`]
//! surface the CLI and benches use. Needs no artifacts; runs on the
//! nano preset.

use std::collections::BTreeMap;
use std::sync::Arc;
use std::time::Duration;

use qrazor::baselines::QRazor;
use qrazor::cluster::{ClusterConfig, ClusterServer};
use qrazor::config::{ModelConfig, ServeConfig};
use qrazor::coordinator::{
    collect_sessions, Engine, FinishReason, Priority, RequestId, Sampling, ServeApi, Server,
    SubmitOptions, TokenEvent,
};
use qrazor::model::quantized::{calibrate, QuantModel};
use qrazor::model::ModelWeights;
use qrazor::util::rng::Rng;

fn model(seed: u64) -> Arc<QuantModel> {
    let cfg = ModelConfig::preset("nano").unwrap();
    let w = ModelWeights::init_random(&cfg, seed);
    let mut rng = Rng::new(seed + 1);
    let seqs: Vec<Vec<u32>> = (0..2)
        .map(|_| (0..16).map(|_| rng.below(cfg.vocab as u64) as u32).collect())
        .collect();
    let cal = calibrate(&w, &seqs);
    Arc::new(QuantModel::build(&w, Box::new(QRazor::w4a4kv4(16)), &cal))
}

/// Target (W4A8 basis) + draft (packed W4A4) pair from one set of
/// weights, for the speculative axes.
fn spec_pair(seed: u64) -> (Arc<QuantModel>, Arc<QuantModel>) {
    let cfg = ModelConfig::preset("nano").unwrap();
    let w = ModelWeights::init_random(&cfg, seed);
    let mut rng = Rng::new(seed + 1);
    let seqs: Vec<Vec<u32>> = (0..2)
        .map(|_| (0..16).map(|_| rng.below(cfg.vocab as u64) as u32).collect())
        .collect();
    let cal = calibrate(&w, &seqs);
    let target = Arc::new(QuantModel::build(&w, Box::new(QRazor::w4a8kv4(16)), &cal));
    let draft = Arc::new(QuantModel::build(&w, Box::new(QRazor::w4a4kv4(16)), &cal));
    (target, draft)
}

/// Seeded mixed workload: greedy and temperature-sampled requests,
/// occasional stop tokens, varied priorities — everything the
/// streaming ≡ batch property must hold over. (No deadlines: expiry
/// is timing-dependent by design and pinned by its own test.)
fn workload(seed: u64, n: usize, vocab: u64) -> Vec<(Vec<u32>, usize, SubmitOptions)> {
    let mut rng = Rng::new(seed);
    (0..n)
        .map(|i| {
            let len = 2 + rng.index(10);
            let prompt: Vec<u32> = (0..len).map(|_| rng.below(vocab) as u32).collect();
            let max_new = 2 + rng.index(6);
            let mut opts = SubmitOptions::new();
            if i % 3 == 1 {
                opts = opts.sampling(Sampling::Temperature {
                    temp: 0.9,
                    seed: seed * 100 + i as u64,
                });
            }
            if i % 4 == 2 {
                opts = opts.stop_token(rng.below(vocab) as u32);
            }
            opts = opts.priority(match i % 3 {
                0 => Priority::Interactive,
                1 => Priority::Standard,
                _ => Priority::Batch,
            });
            (prompt, max_new, opts)
        })
        .collect()
}

/// Token streams + finish reasons via the pre-redesign non-streaming
/// path: a bare `Engine` stepped by `run_to_completion`.
fn engine_baseline(
    model: &Arc<QuantModel>,
    work: &[(Vec<u32>, usize, SubmitOptions)],
) -> BTreeMap<u64, (Vec<u32>, FinishReason)> {
    let mut engine =
        Engine::new(Arc::clone(model), ServeConfig { max_batch: 4, ..Default::default() });
    for (i, (prompt, max_new, opts)) in work.iter().enumerate() {
        engine.submit_request(opts.build(RequestId(i as u64), prompt.clone(), *max_new));
    }
    engine
        .run_to_completion()
        .into_iter()
        .map(|r| (r.id.0, (r.tokens, r.finish)))
        .collect()
}

/// Submit a workload through a [`ServeApi`] front-end and collect the
/// sessions, asserting the per-session streaming ≡ batch identity.
fn api_streams(
    api: &impl ServeApi,
    work: &[(Vec<u32>, usize, SubmitOptions)],
) -> BTreeMap<u64, (Vec<u32>, FinishReason)> {
    for (prompt, max_new, opts) in work {
        api.submit_with(prompt.clone(), *max_new, *opts).unwrap();
    }
    let sessions = collect_sessions(api, work.len()).unwrap();
    sessions
        .into_iter()
        .map(|(id, log)| {
            let resp = log.response.expect("session finished");
            assert_eq!(
                log.tokens(),
                resp.tokens,
                "request {id:?}: concatenated Token payloads must be byte-identical \
                 to the response stream"
            );
            (id.0, (resp.tokens, resp.finish))
        })
        .collect()
}

/// The acceptance property: for mixed greedy/sampled workloads with
/// stop tokens and priorities, the streamed sessions of the threaded
/// server and of 1/2/3-shard clusters are identical — tokens and
/// finish reasons — to the pre-redesign batch engine path.
#[test]
fn streaming_equals_batch_across_engine_and_cluster() {
    let model = model(61);
    let vocab = model.config.vocab as u64;
    for seed in [1u64, 7, 23] {
        let work = workload(seed, 8, vocab);
        let want = engine_baseline(&model, &work);
        let server =
            Server::spawn(Arc::clone(&model), ServeConfig { max_batch: 4, ..Default::default() });
        let got = api_streams(&server, &work);
        server.shutdown();
        assert_eq!(got, want, "seed {seed}: server streams diverged from the batch engine");
        for shards in [1usize, 2, 3] {
            let cluster = ClusterServer::spawn(
                Arc::clone(&model),
                ClusterConfig {
                    shards,
                    serve: ServeConfig { max_batch: 4, ..Default::default() },
                    ..Default::default()
                },
            );
            let got = api_streams(&cluster, &work);
            cluster.shutdown();
            assert_eq!(
                got, want,
                "seed {seed}: {shards}-shard streams diverged from the batch engine"
            );
        }
    }
}

/// Streaming ≡ batch with speculative decoding on: the W4A4 draft at
/// several lookaheads (server and cluster) reproduces the plain
/// engine's streams, and with a self-draft (acceptance exactly 1.0)
/// accepted prefixes demonstrably flush as multi-token batches.
#[test]
fn streaming_equals_batch_with_speculation() {
    let (target, draft) = spec_pair(71);
    let vocab = target.config.vocab as u64;
    let work = workload(5, 8, vocab);
    let want = engine_baseline(&target, &work);
    for k in [2usize, 3] {
        let server = Server::spawn_with_draft(
            Arc::clone(&target),
            Some(Arc::clone(&draft)),
            ServeConfig { max_batch: 4, spec_k: k, ..Default::default() },
        );
        let got = api_streams(&server, &work);
        assert!(server.stats().spec.steps > 0, "k={k}: rounds must run");
        server.shutdown();
        assert_eq!(got, want, "k={k}: speculative server streams diverged");
        let cluster = ClusterServer::spawn_with_draft(
            Arc::clone(&target),
            Some(Arc::clone(&draft)),
            ClusterConfig {
                shards: 2,
                serve: ServeConfig { max_batch: 4, spec_k: k, ..Default::default() },
                ..Default::default()
            },
        );
        let got = api_streams(&cluster, &work);
        cluster.shutdown();
        assert_eq!(got, want, "k={k}: speculative cluster streams diverged");
    }
    // Self-draft: every draft token verifies, so each round commits
    // k + 1 tokens and must arrive as one multi-token Token event.
    let server = Server::spawn_with_draft(
        Arc::clone(&target),
        Some(Arc::clone(&target)),
        ServeConfig { max_batch: 1, spec_k: 3, ..Default::default() },
    );
    let id = server.submit(vec![4, 2, 9], 8, Sampling::Greedy).unwrap();
    let sessions = collect_sessions(&server, 1).unwrap();
    server.shutdown();
    let log = &sessions[&id];
    assert!(
        log.batches.iter().any(|(_, b)| b.len() > 1),
        "an accepted prefix must flush as one batched Token event: {:?}",
        log.batches.iter().map(|(_, b)| b.len()).collect::<Vec<_>>()
    );
}

/// Byte-exact cancellation accounting at the engine level, plain and
/// speculative: a twin engine that never saw the cancelled request
/// holds byte-identical KV (and draft-pool) state after the cancel,
/// and the surviving stream is unchanged.
#[test]
fn cancellation_returns_pool_bytes_exactly_and_leaves_streams_alone() {
    let (target, draft) = spec_pair(81);
    for spec in [false, true] {
        let mk = || {
            let cfg = ServeConfig {
                max_batch: 4,
                spec_k: if spec { 3 } else { 0 },
                ..Default::default()
            };
            if spec {
                Engine::with_draft(Arc::clone(&target), Some(Arc::clone(&draft)), cfg)
            } else {
                Engine::new(Arc::clone(&target), cfg)
            }
        };
        let mut with_victim = mk();
        let mut twin = mk();
        // identical long-running request on both
        with_victim.submit(vec![3, 1, 2], 40, Sampling::Greedy);
        twin.submit(vec![3, 1, 2], 40, Sampling::Greedy);
        for _ in 0..3 {
            with_victim.step();
            twin.step();
        }
        // the victim arrives only on one engine, mid-flight
        let victim = with_victim.submit(vec![7, 8, 9], 30, Sampling::Greedy);
        for _ in 0..4 {
            with_victim.step();
            twin.step();
        }
        assert!(
            with_victim.kv_bytes() > twin.kv_bytes(),
            "spec={spec}: the victim must hold pool bytes while live"
        );
        assert!(with_victim.cancel(victim), "spec={spec}: victim is live");
        assert_eq!(
            with_victim.kv_bytes(),
            twin.kv_bytes(),
            "spec={spec}: cancel must return KV + draft-pool occupancy byte-exactly \
             to the never-submitted baseline"
        );
        assert_eq!(
            with_victim.pool_occupancy().reserved_tokens,
            twin.pool_occupancy().reserved_tokens,
            "spec={spec}: token reservations must match the baseline too"
        );
        // cancelling the same id again finds nothing
        assert!(!with_victim.cancel(victim), "spec={spec}: cancel is idempotent");
        // the cancelled response carries the partial stream
        let cancelled = with_victim
            .take_completed()
            .into_iter()
            .find(|r| r.id == victim)
            .expect("cancelled response delivered");
        assert_eq!(cancelled.finish, FinishReason::Cancelled);
        assert!(!cancelled.tokens.is_empty(), "spec={spec}: victim streamed before cancel");
        // survivor streams on, identical to the twin
        let mut a = with_victim.run_to_completion();
        let mut b = twin.run_to_completion();
        a.sort_by_key(|r| r.id);
        b.sort_by_key(|r| r.id);
        assert_eq!(a.len(), 1);
        assert_eq!(
            a[0].tokens, b[0].tokens,
            "spec={spec}: another request's cancellation must not perturb the stream"
        );
        assert_eq!(with_victim.kv_bytes(), 0, "spec={spec}: full drain");
        assert_eq!(twin.kv_bytes(), 0);
    }
}

/// Queued-request cancellation purges the batcher without a step.
#[test]
fn cancellation_of_a_queued_request_purges_the_queue() {
    let model = model(83);
    let mut e =
        Engine::new(Arc::clone(&model), ServeConfig { max_batch: 1, ..Default::default() });
    let runner = e.submit(vec![1, 2], 20, Sampling::Greedy);
    let queued = e.submit(vec![3, 4], 20, Sampling::Greedy);
    e.step(); // admits only the runner (one batch slot)
    assert!(e.cancel(queued), "still queued → purged");
    let done = e.take_completed();
    let resp = done.iter().find(|r| r.id == queued).expect("answered");
    assert_eq!(resp.finish, FinishReason::Cancelled);
    assert!(resp.tokens.is_empty(), "a queued cancel never generated");
    let rest = e.run_to_completion();
    assert_eq!(rest.len(), 1);
    assert_eq!(rest[0].id, runner);
    assert_eq!(rest[0].tokens.len(), 20);
}

/// Cluster-level cancellation: cancel a running session mid-stream via
/// the `ServeApi`; its partial response matches its streamed prefix
/// (a prefix of the uncancelled baseline stream), every other
/// session's stream is unchanged, and the shard pools drain to zero
/// bytes.
#[test]
fn cancellation_on_the_cluster_leaves_other_streams_unchanged() {
    let model = model(87);
    let vocab = model.config.vocab as u64;
    let serve = ServeConfig { max_batch: 4, max_new_tokens: 512, ..Default::default() };
    // workload: one long-running victim + five short survivors
    let mut rng = Rng::new(3);
    let mut prompts: Vec<Vec<u32>> = vec![vec![9, 1, 4, 4]];
    for _ in 0..5 {
        let len = 2 + rng.index(6);
        prompts.push((0..len).map(|_| rng.below(vocab) as u32).collect());
    }
    // baseline: the same six requests, uncancelled, on a bare engine
    let baseline: BTreeMap<u64, Vec<u32>> = {
        let mut e = Engine::new(Arc::clone(&model), serve.clone());
        for (i, p) in prompts.iter().enumerate() {
            let max_new = if i == 0 { 300 } else { 6 };
            e.submit(p.clone(), max_new, Sampling::Greedy);
        }
        e.run_to_completion().into_iter().map(|r| (r.id.0, r.tokens)).collect()
    };
    let cluster = ClusterServer::spawn(
        Arc::clone(&model),
        ClusterConfig { shards: 2, serve, ..Default::default() },
    );
    let mut ids = Vec::new();
    for (i, p) in prompts.iter().enumerate() {
        let max_new = if i == 0 { 300 } else { 6 };
        ids.push(cluster.submit(p.clone(), max_new, Sampling::Greedy).unwrap());
    }
    let victim = ids[0];
    // collect events by hand so we can cancel the moment the victim
    // demonstrably streams
    let mut logs: BTreeMap<RequestId, Vec<u32>> = BTreeMap::new();
    let mut finished: BTreeMap<RequestId, qrazor::coordinator::Response> = BTreeMap::new();
    let mut cancelled = false;
    while finished.len() < prompts.len() {
        match cluster.next_event().unwrap() {
            TokenEvent::Started { .. } => {}
            TokenEvent::Token { id, tokens, .. } => {
                logs.entry(id).or_default().extend(tokens);
                if id == victim && !cancelled {
                    cluster.cancel(victim).unwrap();
                    cancelled = true;
                }
            }
            TokenEvent::Finished { id, response } => {
                finished.insert(id, response);
            }
        }
    }
    let vresp = &finished[&victim];
    assert_eq!(vresp.finish, FinishReason::Cancelled);
    assert!(!vresp.tokens.is_empty(), "cancel landed after streaming began");
    assert!(vresp.tokens.len() < 300, "cancel landed mid-flight");
    assert_eq!(&vresp.tokens, &logs[&victim], "partial response ≡ streamed prefix");
    let full = &baseline[&victim.0];
    assert_eq!(
        &full[..vresp.tokens.len()],
        &vresp.tokens[..],
        "the partial stream is a prefix of the uncancelled stream"
    );
    for id in &ids[1..] {
        assert_eq!(
            finished[id].tokens,
            baseline[&id.0],
            "survivor {id:?} must stream exactly the baseline tokens"
        );
        assert_eq!(finished[id].finish, FinishReason::Length);
    }
    let report = cluster.shutdown();
    for s in &report.shards {
        assert_eq!(s.final_occupancy.bytes, 0, "shard {} must drain byte-exactly", s.index);
        assert_eq!(s.final_occupancy.reserved_tokens, 0);
    }
}

/// Priority classes reorder queued admission: an interactive arrival
/// jumps the whole standard/batch queue, and the deferral-aging pin
/// then guarantees the overtaken requests go next in queue order —
/// bounded priority inversion, no starvation.
#[test]
fn priority_tiers_order_queued_admission() {
    let model = model(91);
    let mut e =
        Engine::new(Arc::clone(&model), ServeConfig { max_batch: 1, ..Default::default() });
    let submit = |e: &mut Engine, id: u64, p: Priority| {
        let opts = SubmitOptions::new().priority(p);
        e.submit_request(opts.build(RequestId(id), vec![1 + id as u32, 2], 4));
    };
    submit(&mut e, 0, Priority::Standard);
    submit(&mut e, 1, Priority::Batch);
    submit(&mut e, 2, Priority::Standard);
    submit(&mut e, 3, Priority::Interactive);
    let order: Vec<u64> = e.run_to_completion().into_iter().map(|r| r.id.0).collect();
    // Interactive (3) admits first; the overtaken 0, 2, 1 are pinned
    // by deferral aging in their post-sort queue order: standard
    // before batch, arrival order within a class.
    assert_eq!(order, vec![3, 0, 2, 1]);
}

/// A queued request whose admission deadline passes finishes as
/// `Expired` without ever decoding; running requests are unaffected.
/// Pinned at the engine level and through the cluster's `ServeApi`.
#[test]
fn deadline_expires_queued_requests_only() {
    let model = model(93);
    let mut e =
        Engine::new(Arc::clone(&model), ServeConfig { max_batch: 1, ..Default::default() });
    // the runner holds the only batch slot and carries a generous
    // deadline — running work is never expired
    let runner_opts = SubmitOptions::new().deadline(Duration::from_secs(3600));
    e.submit_request(runner_opts.build(RequestId(0), vec![5, 6, 7], 6));
    let doomed_opts = SubmitOptions::new().deadline(Duration::ZERO);
    e.submit_request(doomed_opts.build(RequestId(1), vec![8, 9], 6));
    let mut out = e.run_to_completion();
    out.sort_by_key(|r| r.id);
    assert_eq!(out.len(), 2);
    assert_eq!(out[0].finish, FinishReason::Length);
    assert_eq!(out[0].tokens.len(), 6);
    assert_eq!(out[1].finish, FinishReason::Expired);
    assert!(out[1].tokens.is_empty());
    assert!(e.is_idle());
    assert_eq!(e.kv_bytes(), 0);

    // the same contract through the sharded front-end
    let cluster = ClusterServer::spawn(
        Arc::clone(&model),
        ClusterConfig { shards: 2, ..Default::default() },
    );
    let ok = cluster.submit(vec![1, 2, 3], 4, Sampling::Greedy).unwrap();
    let doomed = cluster
        .submit_with(vec![4, 5], 4, SubmitOptions::new().deadline(Duration::ZERO))
        .unwrap();
    let sessions = collect_sessions(&cluster, 2).unwrap();
    cluster.shutdown();
    let okr = sessions[&ok].response.as_ref().unwrap();
    assert_eq!(okr.finish, FinishReason::Length);
    assert_eq!(okr.tokens.len(), 4);
    let dr = sessions[&doomed].response.as_ref().unwrap();
    assert_eq!(dr.finish, FinishReason::Expired);
    assert!(dr.tokens.is_empty());
}
