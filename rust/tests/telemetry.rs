//! Telemetry acceptance suite: span-tree balance through scheduler
//! churn (cancel, expiry, preemption, speculative rollback) on the
//! bare engine and on a sharded cluster, Chrome trace export
//! validity, registry ≡ JSON ≡ legacy-field consistency, the
//! observe-only contract (streams are byte-identical with telemetry
//! on), and the zero-allocation guarantee of the disabled paths
//! (pinned with a counting global allocator). Runs on the nano
//! preset; no artifacts needed.

use std::alloc::{GlobalAlloc, Layout, System};
use std::cell::Cell;
use std::collections::BTreeMap;
use std::sync::{Arc, Mutex, MutexGuard};
use std::time::Duration;

use qrazor::baselines::{Fp16, QRazor};
use qrazor::cluster::{ClusterConfig, ClusterServer};
use qrazor::config::{ModelConfig, ServeConfig};
use qrazor::coordinator::{
    collect_sessions, Engine, FinishReason, Priority, Request, RequestId, Sampling, ServeApi,
    Server, SubmitOptions,
};
use qrazor::model::quantized::{calibrate, QuantModel};
use qrazor::model::ModelWeights;
use qrazor::obs::{
    self, unbalanced_spans, HotSpan, HotStage, Phase, Stage, StageSpan, StageTimes, TraceBuffer,
    TraceEvent,
};
use qrazor::util::json::Json;
use qrazor::util::rng::Rng;

// ---------------------------------------------------------------- //
// counting allocator: every allocation on a thread bumps that
// thread's counter, so parallel tests never pollute each other's
// reading. Const-initialized TLS (no lazy init, no destructor) keeps
// the allocator itself allocation-free.
// ---------------------------------------------------------------- //

thread_local! {
    static THREAD_ALLOCS: Cell<u64> = const { Cell::new(0) };
}

struct CountingAlloc;

unsafe impl GlobalAlloc for CountingAlloc {
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        THREAD_ALLOCS.with(|c| c.set(c.get() + 1));
        System.alloc(layout)
    }

    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        System.dealloc(ptr, layout)
    }
}

#[global_allocator]
static ALLOC: CountingAlloc = CountingAlloc;

fn allocs_on_this_thread() -> u64 {
    THREAD_ALLOCS.with(|c| c.get())
}

/// The step-timing flag is process-global; every test that flips it
/// (or reads hot-path counters) serializes here so libtest's thread
/// pool cannot interleave enabled and disabled expectations.
fn timing_guard() -> MutexGuard<'static, ()> {
    static LOCK: Mutex<()> = Mutex::new(());
    LOCK.lock().unwrap_or_else(|e| e.into_inner())
}

// ---------------------------------------------------------------- //
// model builders (mirroring the serve_api suite)
// ---------------------------------------------------------------- //

fn model(seed: u64) -> Arc<QuantModel> {
    let cfg = ModelConfig::preset("nano").unwrap();
    let w = ModelWeights::init_random(&cfg, seed);
    let mut rng = Rng::new(seed + 1);
    let seqs: Vec<Vec<u32>> = (0..2)
        .map(|_| (0..16).map(|_| rng.below(cfg.vocab as u64) as u32).collect())
        .collect();
    let cal = calibrate(&w, &seqs);
    Arc::new(QuantModel::build(&w, Box::new(QRazor::w4a4kv4(16)), &cal))
}

fn spec_pair(seed: u64) -> (Arc<QuantModel>, Arc<QuantModel>) {
    let cfg = ModelConfig::preset("nano").unwrap();
    let w = ModelWeights::init_random(&cfg, seed);
    let mut rng = Rng::new(seed + 1);
    let seqs: Vec<Vec<u32>> = (0..2)
        .map(|_| (0..16).map(|_| rng.below(cfg.vocab as u64) as u32).collect())
        .collect();
    let cal = calibrate(&w, &seqs);
    let target = Arc::new(QuantModel::build(&w, Box::new(QRazor::w4a8kv4(16)), &cal));
    let draft = Arc::new(QuantModel::build(&w, Box::new(QRazor::w4a4kv4(16)), &cal));
    (target, draft)
}

/// Fp16 nano model with a one-page KV pool — the deterministic
/// preemption recipe the scheduler suite pins.
fn tight_fp16_engine() -> Engine {
    let cfg = ModelConfig::preset("nano").unwrap();
    let w = ModelWeights::init_random(&cfg, 5);
    let mut rng = Rng::new(6);
    let seqs: Vec<Vec<u32>> = (0..2)
        .map(|_| (0..16).map(|_| rng.below(cfg.vocab as u64) as u32).collect())
        .collect();
    let cal = calibrate(&w, &seqs);
    let qm = QuantModel::build(&w, Box::new(Fp16), &cal);
    Engine::new(
        qm,
        ServeConfig { max_batch: 4, max_new_tokens: 8, kv_pool_tokens: 16, ..Default::default() },
    )
}

fn workload(seed: u64, n: usize, vocab: u64) -> Vec<(Vec<u32>, usize, SubmitOptions)> {
    let mut rng = Rng::new(seed);
    (0..n)
        .map(|i| {
            let len = 2 + rng.index(10);
            let prompt: Vec<u32> = (0..len).map(|_| rng.below(vocab) as u32).collect();
            let max_new = 2 + rng.index(6);
            let mut opts = SubmitOptions::new();
            if i % 3 == 1 {
                opts = opts.sampling(Sampling::Temperature {
                    temp: 0.9,
                    seed: seed * 100 + i as u64,
                });
            }
            opts = opts.priority(match i % 3 {
                0 => Priority::Interactive,
                1 => Priority::Standard,
                _ => Priority::Batch,
            });
            (prompt, max_new, opts)
        })
        .collect()
}

fn instant_count(events: &[TraceEvent], name: &str) -> usize {
    events.iter().filter(|e| e.ph == Phase::Instant && e.name == name).count()
}

// ---------------------------------------------------------------- //
// span balance under churn
// ---------------------------------------------------------------- //

/// Preemption, queued-cancel, running-cancel, deadline expiry, and
/// submit-time rejection in one engine: every request's span tree
/// must close, with the matching lifecycle instants recorded.
#[test]
fn engine_churn_keeps_every_span_tree_closed() {
    let _g = timing_guard();
    obs::set_timing(true);
    let buf = TraceBuffer::new(4096);
    let mut e = tight_fp16_engine();
    e.set_trace(buf.clone(), 0);

    // Batch-tier request fills the one-page pool...
    let mut long = Request::new(RequestId(1), vec![1, 2, 3], 6);
    long.priority = Priority::Batch;
    e.submit_request(long);
    e.step();
    // ...then an interactive arrival forces a preemption.
    let mut vip = Request::new(RequestId(2), vec![4, 5], 4);
    vip.priority = Priority::Interactive;
    e.submit_request(vip);
    e.step();
    // Queued-cancel: a batch request purged before admission.
    let mut queued = Request::new(RequestId(3), vec![6, 7], 4);
    queued.priority = Priority::Batch;
    e.submit_request(queued);
    assert!(e.cancel(RequestId(3)));
    // Running-cancel: the vip is mid-decode after the step above.
    assert!(e.cancel(RequestId(2)));
    // Expiry: a zero deadline dies in the next sweep.
    e.submit_request(
        SubmitOptions::new().deadline(Duration::ZERO).build(RequestId(4), vec![8, 9], 4),
    );
    // Rejection: total need beyond the whole pool.
    e.submit_request(Request::new(RequestId(5), (0..100u32).collect(), 4));

    let mut out = e.run_to_completion();
    out.extend(e.take_completed());
    obs::set_timing(false);

    out.sort_by_key(|r| r.id);
    assert_eq!(out.len(), 5);
    let finishes: Vec<(u64, FinishReason)> = out.iter().map(|r| (r.id.0, r.finish)).collect();
    assert_eq!(
        finishes,
        vec![
            (1, FinishReason::Length),
            (2, FinishReason::Cancelled),
            (3, FinishReason::Cancelled),
            (4, FinishReason::Expired),
            (5, FinishReason::Error),
        ],
    );
    assert!(e.metrics.preemptions >= 1, "the batch request must be preempted");

    let ev = buf.events();
    let bad = unbalanced_spans(&ev);
    assert!(bad.is_empty(), "span trees must close under churn: {bad:?}");
    for name in ["admitted", "preempted", "expired", "rejected"] {
        assert!(instant_count(&ev, name) >= 1, "missing lifecycle instant {name:?}");
    }
    assert!(instant_count(&ev, "cancelled") >= 2, "queued and running cancels both mark");
    // Timing was on: the per-stage histograms saw every step.
    assert!(e.metrics.stages.get(Stage::Decode).is_some(), "decode stage must be timed");
    assert!(e.metrics.stages.get(Stage::Preempt).is_some(), "preempt stage must be timed");
}

/// Speculative draft→verify→rollback churn: rounds are traced as
/// instants, the hot-path counters move, and the trees still close.
#[test]
fn spec_rollback_churn_traces_rounds_and_balances() {
    let _g = timing_guard();
    obs::set_timing(true);
    obs::hot_reset();
    let (target, draft) = spec_pair(11);
    let buf = TraceBuffer::new(4096);
    let mut e = Engine::with_draft(
        target,
        Some(draft),
        ServeConfig { max_batch: 4, spec_k: 3, ..Default::default() },
    );
    e.set_trace(buf.clone(), 0);
    for i in 0..4u64 {
        let mut opts = SubmitOptions::new();
        if i % 2 == 1 {
            opts = opts.sampling(Sampling::Temperature { temp: 0.9, seed: 40 + i });
        }
        e.submit_request(opts.build(RequestId(i), vec![1 + i as u32, 2, 3 + i as u32], 6));
    }
    let out = e.run_to_completion();
    obs::set_timing(false);

    assert_eq!(out.len(), 4);
    assert!(e.metrics.spec.steps > 0, "the workload must speculate");
    let ev = buf.events();
    let bad = unbalanced_spans(&ev);
    assert!(bad.is_empty(), "spec churn must not leak spans: {bad:?}");
    assert!(instant_count(&ev, "spec_round") >= 1, "rounds must be traced");
    let hot = obs::hot_snapshot();
    for want in ["spec_draft", "spec_verify", "packed_attention"] {
        assert!(
            hot.iter().any(|(name, _ns, calls)| *name == want && *calls > 0),
            "hot stage {want:?} must accumulate calls: {hot:?}"
        );
    }
}

// ---------------------------------------------------------------- //
// cluster trace export
// ---------------------------------------------------------------- //

/// Mixed workload (priorities + cancellation + speculation + prefix
/// reuse) on a 2-shard cluster: one shared buffer yields a valid
/// Chrome trace with closed span trees, and the merged registry
/// carries the cluster totals with per-stage histograms.
#[test]
fn cluster_mixed_workload_exports_valid_chrome_trace() {
    let _g = timing_guard();
    obs::set_timing(true);
    let (target, draft) = spec_pair(21);
    let vocab = target.config.vocab as u64;
    let trace = TraceBuffer::new(8192);
    let cluster = ClusterServer::spawn_with_telemetry(
        target,
        Some(draft),
        ClusterConfig {
            shards: 2,
            serve: ServeConfig { max_batch: 2, spec_k: 2, ..Default::default() },
            ..Default::default()
        },
        Some(trace.clone()),
    );
    let work = workload(9, 10, vocab);
    let preamble: Vec<u32> = (0..8u32).map(|i| 1 + i).collect();
    let mut ids = Vec::new();
    for (i, (prompt, max_new, opts)) in work.iter().enumerate() {
        // Even arrivals share an 8-token preamble to exercise the
        // prefix index on whichever shard they land on.
        let mut p = if i % 2 == 0 { preamble.clone() } else { Vec::new() };
        p.extend_from_slice(prompt);
        ids.push(cluster.submit_with(p, *max_new, *opts).unwrap());
    }
    // Cancel one request right away — whether it dies queued, running,
    // or post-finish, the trace must stay balanced.
    cluster.cancel(ids[3]).unwrap();
    let sessions = collect_sessions(&cluster, work.len()).unwrap();
    assert_eq!(sessions.len(), work.len());
    let report = cluster.shutdown();
    obs::set_timing(false);

    let ev = trace.events();
    let bad = unbalanced_spans(&ev);
    assert!(bad.is_empty(), "cluster span trees must close: {bad:?}");
    assert_eq!(trace.dropped(), 0, "the ring must not wrap in this workload");

    // Chrome trace_event export: parses, and every event carries the
    // fields Perfetto requires.
    let chrome = Json::parse(&trace.to_chrome_json().to_string()).unwrap();
    let events = chrome.get("traceEvents").unwrap().as_arr().unwrap();
    assert!(!events.is_empty());
    for e in events {
        for field in ["name", "cat", "ph", "ts", "pid", "tid"] {
            assert!(e.get(field).is_some(), "trace event missing {field}");
        }
    }

    // Merged registry: cluster totals under shard="all", schema-valid
    // JSON, and a merged per-stage latency breakdown.
    let reg = report.registry();
    let all = [("shard", "all")];
    assert_eq!(reg.counter_value("qrazor_requests_submitted", &all), work.len() as u64);
    assert_eq!(reg.counter_value("qrazor_requests_completed", &all), work.len() as u64);
    let snapshot = Json::parse(&reg.to_json().to_string()).unwrap();
    obs::validate_registry_json(&snapshot).unwrap();
    let merged = report.merged_metrics();
    assert!(merged.stages.get(Stage::Decode).is_some(), "merged decode histogram");
    assert!(merged.stages.get(Stage::Publish).is_some(), "merged publish histogram");
}

// ---------------------------------------------------------------- //
// registry consistency
// ---------------------------------------------------------------- //

/// One run, three views: the Prometheus text, the JSON snapshot, and
/// the legacy `Metrics` fields/JSON must all agree on every figure.
#[test]
fn registry_text_json_and_legacy_fields_agree() {
    let _g = timing_guard();
    obs::set_timing(true);
    let m = model(31);
    let vocab = m.config.vocab as u64;
    let mut e = Engine::new(m, ServeConfig { max_batch: 4, ..Default::default() });
    for (i, (prompt, max_new, opts)) in workload(3, 6, vocab).iter().enumerate() {
        e.submit_request(opts.build(RequestId(i as u64), prompt.clone(), *max_new));
    }
    let out = e.run_to_completion();
    obs::set_timing(false);
    assert_eq!(out.len(), 6);

    let metrics = &e.metrics;
    let sh = [("shard", "0")];
    let reg = metrics.to_registry(&sh);

    // Registry accessors ≡ struct fields.
    assert_eq!(reg.counter_value("qrazor_requests_submitted", &sh), metrics.requests_submitted);
    assert_eq!(reg.counter_value("qrazor_requests_completed", &sh), metrics.requests_completed);
    assert_eq!(reg.counter_value("qrazor_prompt_tokens", &sh), metrics.prompt_tokens);
    assert_eq!(reg.counter_value("qrazor_generated_tokens", &sh), metrics.generated_tokens);
    assert_eq!(reg.counter_value("qrazor_scheduler_steps", &sh), metrics.scheduler_steps);
    assert_eq!(reg.gauge_value("qrazor_kv_bytes_peak", &sh), metrics.kv_bytes_peak as f64);
    assert_eq!(reg.hist("qrazor_ttft_seconds", &sh).unwrap().len(), metrics.ttft.len());
    assert_eq!(reg.hist("qrazor_latency_seconds", &sh).unwrap().len(), metrics.latency.len());
    let decode = [("shard", "0"), ("stage", "decode")];
    assert!(reg.hist("qrazor_stage_ms", &decode).is_some(), "timed run exports stage hists");

    // Prometheus text carries the same numbers.
    let text = reg.render_prometheus();
    for (name, v) in [
        ("qrazor_requests_submitted", metrics.requests_submitted),
        ("qrazor_requests_completed", metrics.requests_completed),
        ("qrazor_generated_tokens", metrics.generated_tokens),
    ] {
        let line = format!("{name}{{shard=\"0\"}} {v}");
        assert!(text.contains(&line), "prometheus text missing {line:?}:\n{text}");
    }

    // JSON snapshot: schema-valid, and the flat keys hold the same
    // values as the fields and the legacy Metrics::to_json dump.
    let snapshot = Json::parse(&reg.to_json().to_string()).unwrap();
    obs::validate_registry_json(&snapshot).unwrap();
    let counters = snapshot.get("counters").unwrap();
    for (key, v) in [
        ("qrazor_requests_submitted{shard=0}", metrics.requests_submitted),
        ("qrazor_generated_tokens{shard=0}", metrics.generated_tokens),
        ("qrazor_scheduler_steps{shard=0}", metrics.scheduler_steps),
    ] {
        let got = counters.get(key).and_then(|j| j.as_f64());
        assert_eq!(got, Some(v as f64), "snapshot counter {key}");
    }
    let hists = snapshot.get("histograms").unwrap();
    let ttft = hists.get("qrazor_ttft_seconds{shard=0}").unwrap();
    assert_eq!(ttft.get("count").and_then(|j| j.as_usize()), Some(metrics.ttft.len()));
    let legacy = metrics.to_json();
    assert_eq!(
        legacy.get("generated_tokens").and_then(|j| j.as_usize()),
        Some(metrics.generated_tokens as usize),
        "legacy JSON agrees with the registry"
    );
}

// ---------------------------------------------------------------- //
// observe-only contract
// ---------------------------------------------------------------- //

/// Token streams and finish reasons are byte-identical with stage
/// timing and tracing enabled — instrumentation never perturbs
/// scheduling.
#[test]
fn token_streams_identical_with_telemetry_enabled() {
    let _g = timing_guard();
    let m = model(61);
    let vocab = m.config.vocab as u64;
    let work = workload(7, 8, vocab);

    // Baseline: telemetry fully off.
    obs::set_timing(false);
    let mut base = Engine::new(Arc::clone(&m), ServeConfig { max_batch: 4, ..Default::default() });
    for (i, (prompt, max_new, opts)) in work.iter().enumerate() {
        base.submit_request(opts.build(RequestId(i as u64), prompt.clone(), *max_new));
    }
    let want: BTreeMap<u64, (Vec<u32>, FinishReason)> = base
        .run_to_completion()
        .into_iter()
        .map(|r| (r.id.0, (r.tokens, r.finish)))
        .collect();

    // Same workload through a traced, timed server.
    obs::set_timing(true);
    let trace = TraceBuffer::new(8192);
    let server = Server::spawn_with_telemetry(
        Arc::clone(&m),
        None,
        ServeConfig { max_batch: 4, ..Default::default() },
        Some(trace.clone()),
    );
    for (prompt, max_new, opts) in &work {
        server.submit_with(prompt.clone(), *max_new, *opts).unwrap();
    }
    let sessions = collect_sessions(&server, work.len()).unwrap();
    let got: BTreeMap<u64, (Vec<u32>, FinishReason)> = sessions
        .into_iter()
        .map(|(id, log)| {
            let resp = log.response.expect("session finished");
            (id.0, (resp.tokens, resp.finish))
        })
        .collect();
    let metrics = server.shutdown_with_metrics().expect("serve worker");
    obs::set_timing(false);

    assert_eq!(got, want, "telemetry must be observe-only");
    assert!(!metrics.stages.is_empty(), "the timed run did record stages");
    assert!(!trace.events().is_empty(), "the traced run did record spans");
    assert!(unbalanced_spans(&trace.events()).is_empty());
}

// ---------------------------------------------------------------- //
// disabled-path overhead
// ---------------------------------------------------------------- //

/// With timing off and the trace buffer disabled, the hot-path
/// primitives — stage spans, hot spans, trace emits — allocate
/// nothing and record nothing.
#[test]
fn disabled_telemetry_allocates_nothing_on_hot_paths() {
    let _g = timing_guard();
    obs::set_timing(false);
    let buf = TraceBuffer::new(64);
    buf.set_enabled(false);
    let mut times = StageTimes::default();

    let before = allocs_on_this_thread();
    for _ in 0..1000 {
        let span = StageSpan::begin();
        span.finish(Stage::Decode, &mut times);
        let hot = HotSpan::begin();
        hot.finish(HotStage::PackedAttention);
        buf.emit(1, 0, "request", Phase::Begin, Vec::new());
        buf.emit(1, 0, "request", Phase::End, Vec::new());
    }
    let after = allocs_on_this_thread();

    assert_eq!(after, before, "disabled telemetry must not allocate");
    assert!(times.is_empty(), "disabled stage spans must not accumulate");
    assert!(buf.events().is_empty(), "disabled buffer must not record");
    assert_eq!(buf.dropped(), 0);
}
