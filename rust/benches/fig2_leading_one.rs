//! Figure 2 — (a,b) histograms of the leading-'1' position of
//! activations / Query / Key after stage-1 quantization (before 4-bit
//! compression), and (c) the fraction of zeroed elements before vs
//! after compression, per tensor kind.
//!
//! Shape claims: the activation mass concentrates in a mid band of bit
//! positions; the fraction of groups whose leading one sits in the top
//! bits is small (paper: ~9% above the 12th bit); zeroed-element growth
//! is large for activations/weights and modest for Q/K/V.

use qrazor::eval::harness::{build_experiment, EvalScale};
use qrazor::quant::{Granularity, QuantTensor};
use qrazor::sdr::signmag::{group_or, leading_one};
use qrazor::sdr::{SdrMatrix, SdrSpec};
use qrazor::util::stats::Histogram;

fn main() -> anyhow::Result<()> {
    let scale = EvalScale::from_env();
    let preset = std::env::var("BENCH_MODELS").unwrap_or_else(|_| "tiny".into());
    let exp = build_experiment(preset.split(',').next().unwrap().trim(), scale, 1)?;

    let kinds: Vec<(&str, Vec<String>, u32)> = vec![
        ("activation", (0..exp.config.layers).map(|l| format!("l{l}.attn_in")).collect(), 16),
        ("query", (0..exp.config.layers).map(|l| format!("l{l}.q")).collect(), 16),
        ("key", (0..exp.config.layers).map(|l| format!("l{l}.k")).collect(), 8),
        ("value", (0..exp.config.layers).map(|l| format!("l{l}.v")).collect(), 8),
        ("weight", vec![], 8), // handled specially below
    ];

    println!("\n=== Fig. 2(a,b) — leading-one position of per-group OR (stage-1 lattice) ===");
    let mut zeroed: Vec<(String, f64, f64)> = Vec::new();
    for (kind, sites, bits) in &kinds {
        let mut hist = Histogram::new(0.0, *bits as f64, *bits as usize);
        let mut zero_before = 0usize;
        let mut zero_after = 0usize;
        let mut total = 0usize;
        let mut observe = |q: &QuantTensor, group: usize| {
            let spec = SdrSpec::new(*&q.bits, 4, group);
            let cols = q.shape[1];
            for row in q.values.chunks(cols) {
                for chunk in row.chunks(group) {
                    if let Some(r) = leading_one(group_or(chunk)) {
                        hist.push(r as f64 + 0.5);
                    }
                }
            }
            zero_before += q.values.iter().filter(|&&v| v == 0).count();
            let m = SdrMatrix::compress(spec, q);
            zero_after += m.codes.iter().filter(|c| c.code == 0).count();
            total += q.values.len();
        };
        if *kind == "weight" {
            for l in &exp.weights.layers {
                for w in [&l.wq, &l.wk, &l.wv, &l.wo, &l.w_gate, &l.w_up, &l.w_down] {
                    observe(&QuantTensor::quantize(w, 8, Granularity::PerChannel), 16);
                }
            }
        } else {
            for site in sites {
                let sample = exp.cal.sample(site).expect("calibrated site");
                observe(&QuantTensor::quantize(sample, *bits, Granularity::PerTensor), 16);
            }
        }
        println!("\n[{kind}] ({bits}-bit base, g16 OR leading-one):");
        print!("{}", hist.ascii(|i| format!("bit {i}"), 40));
        // fraction of groups with leading one in the top quarter of bits
        let top_start = (*bits as usize) * 3 / 4;
        let top: f64 = (top_start..*bits as usize).map(|i| hist.frac(i)).sum();
        println!("groups with leading-one ≥ bit {top_start}: {:.1}%", top * 100.0);
        zeroed.push((
            kind.to_string(),
            100.0 * zero_before as f64 / total as f64,
            100.0 * zero_after as f64 / total as f64,
        ));
    }

    println!("\n=== Fig. 2(c) — zeroed elements before/after 4-bit compression ===");
    println!("{:<12} {:>10} {:>10}", "kind", "before %", "after %");
    for (k, b, a) in &zeroed {
        println!("{:<12} {:>10.2} {:>10.2}", k, b, a);
        assert!(a >= b, "{k}: compression cannot un-zero elements");
    }
    // activations/weights gain zeros substantially more than V
    let get = |n: &str| zeroed.iter().find(|(k, _, _)| k == n).unwrap();
    let act_gain = get("activation").2 - get("activation").1;
    let v_gain = get("value").2 - get("value").1;
    assert!(
        act_gain > v_gain,
        "activation zero-gain ({act_gain:.2}) should exceed value's ({v_gain:.2})"
    );
    println!("fig2 OK");
    Ok(())
}
