//! Cold-start economics of the packed checkpoint: how fast a serving
//! process reaches its first token from a `qrazor.ckpt.v1` artifact
//! versus re-quantizing the FP model in-process, and how little FP
//! memory the streaming writer (`quantize --out --resident-layers`)
//! keeps resident while packing.
//!
//! Axes:
//! * **spawn**: median wall time of (a) `QuantModel::build` (the
//!   re-quantization path), (b) `Artifact::open` + eager verified
//!   load, (c) `Artifact::open` + cold demand-paged load — plus the
//!   first-token forward for each. The cold load must beat
//!   re-quantization by ≥5× (the artifact's reason to exist).
//! * **writer residency**: `write_from_checkpoint` peak resident FP
//!   bytes under a 1-layer budget versus the in-memory writer (which
//!   by definition holds the whole FP model) — must shrink ≥2×.
//!
//! `--smoke` runs fewer reps for CI; the assertions are identical.

use std::time::Instant;

use qrazor::artifact::{write_from_checkpoint, write_quant_model, Artifact, LoadMode};
use qrazor::config::ModelConfig;
use qrazor::model::checkpoint::save_model;
use qrazor::model::quantized::{calibrate, CalibrationData, QuantModel};
use qrazor::model::ModelWeights;
use qrazor::policy::QuantPolicy;
use qrazor::util::rng::Rng;

fn median(mut v: Vec<f64>) -> f64 {
    v.sort_by(|a, b| a.partial_cmp(b).unwrap());
    v[v.len() / 2]
}

fn time_ms(f: &mut dyn FnMut()) -> f64 {
    let t0 = Instant::now();
    f();
    t0.elapsed().as_secs_f64() * 1e3
}

fn setup() -> (ModelWeights, CalibrationData, Vec<u32>) {
    let cfg = ModelConfig::preset("nano").unwrap();
    let w = ModelWeights::init_random(&cfg, 11);
    let mut rng = Rng::new(12);
    let seqs: Vec<Vec<u32>> = (0..3)
        .map(|_| (0..24).map(|_| rng.below(cfg.vocab as u64) as u32).collect())
        .collect();
    let cal = calibrate(&w, &seqs);
    let prompt = seqs[0][..8].to_vec();
    (w, cal, prompt)
}

fn spawn_axis(reps: usize) {
    let (w, cal, prompt) = setup();
    let policy = QuantPolicy::parse("w4a4kv4:16").unwrap();
    let dir = std::env::temp_dir().join("qrazor_ckpt_spawn");
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("spawn.qrzk");
    let qm = QuantModel::build(&w, policy.clone(), &cal);
    let stats = write_quant_model(&path, &qm, None).unwrap();
    drop(qm);

    let mut build_ms = Vec::new();
    let mut eager_ms = Vec::new();
    let mut cold_ms = Vec::new();
    let mut first_tok_ms = Vec::new();
    for _ in 0..reps {
        build_ms.push(time_ms(&mut || {
            let m = QuantModel::build(&w, policy.clone(), &cal);
            std::hint::black_box(&m);
        }));
        eager_ms.push(time_ms(&mut || {
            let m = Artifact::open(&path).unwrap().load_model(LoadMode::Eager).unwrap();
            std::hint::black_box(&m);
        }));
        let mut loaded = None;
        cold_ms.push(time_ms(&mut || {
            loaded = Some(Artifact::open(&path).unwrap().load_model(LoadMode::Cold).unwrap());
        }));
        let m = loaded.unwrap();
        first_tok_ms.push(time_ms(&mut || {
            std::hint::black_box(&m.forward_full(&prompt));
        }));
    }
    let (b, e, c, f) =
        (median(build_ms), median(eager_ms), median(cold_ms), median(first_tok_ms));
    println!("spawn axis ({reps} reps, nano, w4a4kv4:16, {} B artifact):", stats.bytes_written);
    println!("  re-quantize (QuantModel::build)      {b:>9.3} ms  + first token {f:.3} ms");
    println!("  load --load (eager, verified)        {e:>9.3} ms  + first token {f:.3} ms");
    println!("  load --load --cold (demand-paged)    {c:>9.3} ms  + first token {f:.3} ms");
    println!("  cold-load speedup over re-quantize   {:>9.1}x", b / c);
    assert!(
        b / c >= 5.0,
        "cold load must be >=5x faster than re-quantization (build {b:.3} ms, load {c:.3} ms)"
    );
    std::fs::remove_file(&path).ok();
}

fn residency_axis() {
    let (w, cal, _) = setup();
    let policy = QuantPolicy::parse("w4a4kv4:16").unwrap();
    let dir = std::env::temp_dir().join("qrazor_ckpt_spawn");
    std::fs::create_dir_all(&dir).unwrap();
    let ckpt = dir.join("resid_fp.qrzc");
    let out = dir.join("resid.qrzk");
    save_model(&ckpt, &w).unwrap();
    let full_fp = w.config.param_count() * 4;

    let qm = QuantModel::build(&w, policy.clone(), &cal);
    let mem = write_quant_model(&out, &qm, None).unwrap();
    drop(qm);
    println!("writer residency axis (nano, {full_fp} B FP model):");
    println!(
        "  in-memory writer                     peak {:>9} B ({} layers resident)",
        mem.peak_resident_bytes, mem.resident_layers
    );
    for budget in [1usize, 2] {
        let stats = write_from_checkpoint(&out, &ckpt, &w.config, &policy, &cal, None, budget)
            .unwrap();
        println!(
            "  streaming --resident-layers {budget}         peak {:>9} B ({} layers resident)",
            stats.peak_resident_bytes, stats.resident_layers
        );
        assert!(
            stats.resident_layers <= budget,
            "budget {budget} exceeded: {}",
            stats.resident_layers
        );
        assert!(
            stats.peak_resident_bytes * 2 <= mem.peak_resident_bytes,
            "streaming peak {} must be at least 2x under the in-memory peak {}",
            stats.peak_resident_bytes,
            mem.peak_resident_bytes
        );
    }
    for p in [&ckpt, &out] {
        std::fs::remove_file(p).ok();
    }
}

fn main() {
    let smoke = std::env::args().any(|a| a == "--smoke");
    let reps = if smoke { 7 } else { 31 };
    spawn_axis(reps);
    residency_axis();
    println!("ckpt_spawn OK");
}
