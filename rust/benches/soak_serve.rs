//! Network-front-end soak: thousands of concurrent loopback streaming
//! sessions against a multi-shard cluster behind `HttpServer`, with
//! mixed tenants, priority classes, shared prefixes, tight admission
//! deadlines, and mid-stream client disconnects. Every session drains
//! its stream through the protocol-checking client, so a single
//! malformed frame fails the run.
//!
//! Reports TTFT and inter-token p50/p99, finish-reason counts
//! (deadline expiries included), disconnect-cancels, and per-tenant
//! admission/throttle counters, then asserts the invariants the
//! front-end promises: zero protocol errors, every session resolved
//! (completed or cancelled), the packed KV pools drained byte-exactly
//! to zero, and — on the throttle axis — a rate-capped tenant admitted
//! within 10% of its token-bucket budget while an uncapped tenant
//! rides along unthrottled.
//!
//! `--smoke` shrinks the session count for CI; `--sessions N` and
//! `--shards N` override. `--metrics-out`, `--registry-json`, and
//! `--trace-out` write the Prometheus text, `qrazor.registry.v1`
//! snapshot, and Chrome-trace artifacts (fetched over the wire, so
//! the endpoints themselves are exercised).

use std::collections::BTreeMap;
use std::net::SocketAddr;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::thread;
use std::time::{Duration, Instant};

use qrazor::baselines::QRazor;
use qrazor::cluster::{ClusterConfig, ClusterServer};
use qrazor::config::{ModelConfig, ServeConfig};
use qrazor::model::quantized::{calibrate, QuantModel};
use qrazor::model::ModelWeights;
use qrazor::net::{client, parse_tenants, HttpServer, NetConfig};
use qrazor::obs::{self, TraceBuffer};
use qrazor::util::json::Json;
use qrazor::util::rng::Rng;
use qrazor::util::stats::Percentiles;

fn build_model(seed: u64) -> Arc<QuantModel> {
    let cfg = ModelConfig::preset("nano").unwrap();
    let w = ModelWeights::init_random(&cfg, seed);
    let mut rng = Rng::new(seed + 1);
    let seqs: Vec<Vec<u32>> = (0..2)
        .map(|_| (0..16).map(|_| rng.below(cfg.vocab as u64) as u32).collect())
        .collect();
    let cal = calibrate(&w, &seqs);
    Arc::new(QuantModel::build(&w, Box::new(QRazor::w4a4kv4(16)), &cal))
}

/// What one client session observed.
#[derive(Default)]
struct SessionResult {
    ttft_s: Option<f64>,
    /// Per-token inter-arrival gaps (batched chunks amortized).
    gaps: Vec<f64>,
    tokens: usize,
    finish: Option<String>,
    disconnected: bool,
    proto_error: Option<String>,
}

/// One streaming session: submit, time the frames, optionally hang up
/// mid-stream. Any wire-shape surprise lands in `proto_error`.
fn run_session(addr: SocketAddr, i: usize, vocab: u64) -> SessionResult {
    // Smear connection attempts so the accept backlog never overflows.
    thread::sleep(Duration::from_millis((i % 97) as u64));
    let mut res = SessionResult::default();

    let tenant = match i % 3 {
        0 => None,
        1 => Some("free"),
        _ => Some("pro"),
    };
    let mode = if i % 2 == 0 { "sse" } else { "jsonl" };
    let disconnect = i % 10 == 7;
    let deadline = i % 17 == 5;
    // Disconnectors ask for a long stream so plenty of generation
    // remains to cancel; everyone else stays short.
    let max_tokens = if disconnect { 192 } else { 16 };
    // Half the fleet shares a prompt preamble (prefix-cache traffic),
    // the rest are random.
    let prompt: Vec<u32> = if i % 2 == 0 {
        let mut p = vec![5, 9, 2, 6, 5, 3, 5, 8];
        p.push((i % 50) as u32 + 1);
        p
    } else {
        let mut rng = Rng::new(1000 + i as u64);
        (0..6).map(|_| rng.below(vocab) as u32).collect()
    };
    let prompt_json: Vec<String> = prompt.iter().map(|t| t.to_string()).collect();
    let mut body = format!(
        "{{\"prompt\":[{}],\"max_tokens\":{max_tokens},\"stream\":\"{mode}\"",
        prompt_json.join(",")
    );
    match i % 7 {
        0 => body.push_str(",\"priority\":\"interactive\""),
        3 => body.push_str(",\"priority\":\"batch\""),
        _ => {}
    }
    if deadline {
        body.push_str(",\"deadline_ms\":1");
    }
    body.push('}');

    let t0 = Instant::now();
    let mut reply = match client::post_completions(addr, tenant, &body) {
        Ok(r) => r,
        Err(e) => {
            res.proto_error = Some(format!("request failed: {e}"));
            return res;
        }
    };
    if reply.status != 200 {
        res.proto_error = Some(format!("unexpected status {}", reply.status));
        return res;
    }
    let mut last: Option<Instant> = None;
    loop {
        match reply.next_json() {
            Ok(Some(frame)) => {
                let now = Instant::now();
                match frame.get("object").and_then(|o| o.as_str()) {
                    Some("started") => {}
                    Some("chunk") => {
                        let n = frame
                            .get("tokens")
                            .and_then(|t| t.as_arr())
                            .map(|a| a.len())
                            .unwrap_or(0);
                        if res.ttft_s.is_none() {
                            res.ttft_s = Some(t0.elapsed().as_secs_f64());
                        } else if let Some(prev) = last {
                            let gap = (now - prev).as_secs_f64();
                            for _ in 0..n {
                                res.gaps.push(gap / n.max(1) as f64);
                            }
                        }
                        last = Some(now);
                        res.tokens += n;
                        if disconnect {
                            // Dropping the reply closes the socket:
                            // the server must cancel the session.
                            res.disconnected = true;
                            return res;
                        }
                    }
                    Some("done") => {
                        res.finish = frame
                            .get("response")
                            .and_then(|r| r.get("finish_reason"))
                            .and_then(|f| f.as_str())
                            .map(String::from);
                    }
                    other => {
                        res.proto_error = Some(format!("unknown frame object {other:?}"));
                        return res;
                    }
                }
            }
            Ok(None) => break,
            Err(e) => {
                res.proto_error = Some(e.to_string());
                return res;
            }
        }
    }
    if res.finish.is_none() {
        res.proto_error = Some("stream ended without a done frame".into());
    }
    res
}

fn wait_drained<A: qrazor::coordinator::ServeApi + Send + 'static>(http: &HttpServer<A>) {
    let deadline = Instant::now() + Duration::from_secs(120);
    loop {
        let st = http.stats();
        if st.in_flight() == 0 && st.occupancy.bytes == 0 {
            return;
        }
        assert!(Instant::now() < deadline, "server never drained: {st:?}");
        thread::sleep(Duration::from_millis(20));
    }
}

/// The main soak: `sessions` concurrent streaming clients against a
/// `shards`-way cluster, all invariants checked after the dust settles.
fn soak_axis(
    model: &Arc<QuantModel>,
    sessions: usize,
    shards: usize,
    smoke: bool,
    metrics_out: &str,
    registry_out: &str,
    trace_out: &str,
) {
    let vocab = 256u64; // nano preset
    let serve = ServeConfig { max_batch: 8, max_new_tokens: 256, ..ServeConfig::default() };
    let cfg = ClusterConfig { shards, serve, ..ClusterConfig::default() };
    let trace = TraceBuffer::with_default_capacity();
    let cluster =
        ClusterServer::spawn_with_telemetry(Arc::clone(model), None, cfg, Some(Arc::clone(&trace)));
    let tenants = parse_tenants("free;pro:priority=interactive").unwrap();
    let net_cfg = NetConfig { tenants, ..NetConfig::default() };
    let http = HttpServer::bind(cluster, net_cfg, "127.0.0.1:0", Some(trace)).unwrap();
    let addr = http.addr();

    println!("soak: {sessions} concurrent sessions, {shards} shards, addr {addr}");
    let t0 = Instant::now();
    let results: Arc<Mutex<Vec<SessionResult>>> = Arc::new(Mutex::new(Vec::new()));
    let mut handles = Vec::with_capacity(sessions);
    for i in 0..sessions {
        let results = Arc::clone(&results);
        let h = thread::Builder::new()
            .stack_size(256 << 10)
            .spawn(move || {
                let r = run_session(addr, i, vocab);
                results.lock().unwrap().push(r);
            })
            .expect("spawn session thread");
        handles.push(h);
    }
    for h in handles {
        h.join().unwrap();
    }
    let wall = t0.elapsed().as_secs_f64();
    wait_drained(&http);

    let results = Arc::try_unwrap(results).ok().unwrap().into_inner().unwrap();
    assert_eq!(results.len(), sessions);
    let mut ttft = Percentiles::default();
    let mut gaps = Percentiles::default();
    let mut finishes: BTreeMap<String, usize> = BTreeMap::new();
    let mut disconnects = 0usize;
    let mut proto_errors = 0usize;
    let mut streamed_tokens = 0usize;
    for r in &results {
        if let Some(e) = &r.proto_error {
            proto_errors += 1;
            eprintln!("protocol error: {e}");
        }
        if let Some(t) = r.ttft_s {
            ttft.push(t);
        }
        for g in &r.gaps {
            gaps.push(*g);
        }
        streamed_tokens += r.tokens;
        if r.disconnected {
            disconnects += 1;
        }
        if let Some(f) = &r.finish {
            *finishes.entry(f.clone()).or_insert(0) += 1;
        }
    }
    let resolved: usize = finishes.values().sum();
    let expiries = finishes.get("expired").copied().unwrap_or(0);
    let cancels = http.disconnect_cancels();
    let throttles: u64 = http
        .tenant_counters()
        .iter()
        .map(|t| t.throttled_rate + t.throttled_quota)
        .sum();

    println!("  wall {wall:.2}s  streamed_tokens {streamed_tokens}");
    println!(
        "  ttft_s      p50 {:.4}  p99 {:.4}  (n={})",
        ttft.pct(50.0),
        ttft.pct(99.0),
        ttft.len()
    );
    println!(
        "  intertok_s  p50 {:.5}  p99 {:.5}  (n={})",
        gaps.pct(50.0),
        gaps.pct(99.0),
        gaps.len()
    );
    println!("  finishes {finishes:?}  expiries {expiries}  disconnects {disconnects}");
    println!("  disconnect_cancels {cancels}  throttles {throttles}");
    for t in http.tenant_counters() {
        println!(
            "  tenant {:<10} admitted {:<6} throttled_rate {} throttled_quota {} dropped {}",
            t.name, t.admitted, t.throttled_rate, t.throttled_quota, t.events_dropped
        );
    }

    // Invariants: a clean wire, every session resolved or cancelled,
    // and the disconnects actually noticed by the server.
    assert_eq!(proto_errors, 0, "protocol errors on the wire");
    assert_eq!(resolved + disconnects, sessions, "unresolved sessions");
    assert!(
        cancels >= (disconnects * 4 / 5) as u64,
        "server noticed {cancels} of {disconnects} disconnects"
    );
    assert_eq!(throttles, 0, "no tenant is rate-limited on this axis");

    // Artifacts over the wire, so the endpoints themselves soak.
    let (st, prom) = client::get(addr, "/metrics").unwrap();
    assert_eq!(st, 200);
    let (st, trace_json) = client::get(addr, "/trace").unwrap();
    assert_eq!(st, 200);
    let (st, health) = client::get(addr, "/health").unwrap();
    assert_eq!(st, 200);
    if !metrics_out.is_empty() {
        std::fs::write(metrics_out, &prom).expect("write metrics artifact");
    }
    if !trace_out.is_empty() {
        std::fs::write(trace_out, &trace_json).expect("write trace artifact");
    }

    // KV pools must have drained byte-exactly on every shard.
    let cluster = http.shutdown();
    let report = cluster.shutdown();
    for s in &report.shards {
        assert_eq!(s.final_occupancy.bytes, 0, "shard {} holds KV bytes after drain", s.index);
    }
    assert_eq!(report.total_completed() as usize, sessions, "completions (incl. cancels)");

    let reg_json = report.registry().to_json();
    if !registry_out.is_empty() {
        std::fs::write(registry_out, reg_json.to_string()).expect("write registry artifact");
    }
    if smoke {
        obs::validate_registry_json(&reg_json).expect("registry snapshot schema");
        let parsed = Json::parse(&trace_json).expect("trace endpoint JSON");
        assert!(
            parsed.get("traceEvents").and_then(|t| t.as_arr()).is_some(),
            "trace endpoint shape"
        );
        let h = Json::parse(&health).expect("health endpoint JSON");
        qrazor::obs::validate_health_json(&h).expect("health schema");
        assert!(prom.contains("qrazor_net_http_requests"), "net counters in /metrics");
    }
}

/// Fairness axis: hammer a rate-capped tenant and an uncapped one
/// side by side; the capped tenant's admitted count must land within
/// 10% of its token-bucket budget and the open tenant must never see
/// a 429.
fn throttle_axis(model: &Arc<QuantModel>, smoke: bool) {
    let rps = 40.0;
    let burst = 5.0;
    let serve = ServeConfig { max_batch: 8, max_new_tokens: 8, ..ServeConfig::default() };
    let cluster = ClusterServer::spawn(
        Arc::clone(model),
        ClusterConfig { shards: 2, serve, ..ClusterConfig::default() },
    );
    let tenants = parse_tenants("capped:rps=40,burst=5;open").unwrap();
    let net_cfg = NetConfig { tenants, ..NetConfig::default() };
    let http = HttpServer::bind(cluster, net_cfg, "127.0.0.1:0", None).unwrap();
    let addr = http.addr();

    let window = Duration::from_millis(if smoke { 1500 } else { 3000 });
    let stop = Arc::new(AtomicBool::new(false));
    // [capped_ok, capped_429, open_ok, open_429]
    let counters: Arc<[AtomicU64; 4]> = Arc::new(Default::default());
    let errors = Arc::new(AtomicU64::new(0));
    let mut handles = Vec::new();
    let t0 = Instant::now();
    for (slot, tenant) in [(0usize, "capped"), (2usize, "open")] {
        for _ in 0..4 {
            let stop = Arc::clone(&stop);
            let counters = Arc::clone(&counters);
            let errors = Arc::clone(&errors);
            handles.push(thread::spawn(move || {
                let body = r#"{"prompt":[1,2,3],"max_tokens":1,"stream":"json"}"#;
                while !stop.load(Ordering::Relaxed) {
                    match client::post_completions(addr, Some(tenant), body) {
                        Ok(reply) => {
                            let idx = if reply.status == 200 {
                                slot
                            } else if reply.status == 429 {
                                // Back off instead of spinning on
                                // instant rejections; still attempts
                                // far faster than the refill rate.
                                thread::sleep(Duration::from_millis(2));
                                slot + 1
                            } else {
                                errors.fetch_add(1, Ordering::Relaxed);
                                continue;
                            };
                            counters[idx].fetch_add(1, Ordering::Relaxed);
                            let _ = reply.read_body();
                        }
                        Err(_) => {
                            errors.fetch_add(1, Ordering::Relaxed);
                        }
                    }
                }
            }));
        }
    }
    thread::sleep(window);
    stop.store(true, Ordering::Relaxed);
    let elapsed = t0.elapsed().as_secs_f64();
    for h in handles {
        h.join().unwrap();
    }
    wait_drained(&http);

    let capped_ok = counters[0].load(Ordering::Relaxed) as f64;
    let capped_429 = counters[1].load(Ordering::Relaxed);
    let open_ok = counters[2].load(Ordering::Relaxed) as f64;
    let open_429 = counters[3].load(Ordering::Relaxed);
    let budget = burst + rps * elapsed;
    println!(
        "throttle: capped admitted {capped_ok} (budget {budget:.1}, 429s {capped_429})  \
         open admitted {open_ok} (429s {open_429})"
    );
    for t in http.tenant_counters() {
        println!(
            "  tenant {:<10} admitted {:<6} throttled_rate {} throttled_quota {}",
            t.name, t.admitted, t.throttled_rate, t.throttled_quota
        );
    }
    assert_eq!(errors.load(Ordering::Relaxed), 0, "transport errors during hammer");
    assert!(capped_429 > 0, "capped tenant was never throttled");
    assert!(
        capped_ok >= 0.9 * budget && capped_ok <= 1.1 * budget + 1.0,
        "capped tenant admitted {capped_ok} vs budget {budget:.1} (±10%)"
    );
    assert_eq!(open_429, 0, "open tenant saw a 429");
    assert!(open_ok > capped_ok, "open tenant should outrun the capped one");

    let report = http.shutdown().shutdown();
    for s in &report.shards {
        assert_eq!(s.final_occupancy.bytes, 0, "shard {} holds KV bytes after drain", s.index);
    }
}

fn main() {
    let argv: Vec<String> = std::env::args().collect();
    let smoke = argv.iter().any(|a| a == "--smoke");
    let arg_val = |flag: &str| -> Option<String> {
        argv.iter().position(|a| a == flag).and_then(|i| argv.get(i + 1)).cloned()
    };
    let sessions: usize = arg_val("--sessions")
        .and_then(|s| s.parse().ok())
        .unwrap_or(if smoke { 128 } else { 1200 });
    let shards: usize = arg_val("--shards").and_then(|s| s.parse().ok()).unwrap_or(2);
    let metrics_out = arg_val("--metrics-out").unwrap_or_default();
    let registry_out = arg_val("--registry-json").unwrap_or_default();
    let trace_out = arg_val("--trace-out").unwrap_or_default();

    let model = build_model(7);
    soak_axis(&model, sessions, shards, smoke, &metrics_out, &registry_out, &trace_out);
    throttle_axis(&model, smoke);
    println!("soak_serve OK");
}
