//! Table 10 (Appendix A.6) — second architecture (the Mistral-7B
//! analog: GQA attention) compared against the weight-scaling baseline
//! families at W4A4: SmoothQuant, OS+-class (SmoothQuant α=0.75), and
//! AWQ-class, plus QRazor g16/g32 and W4A4KV4 variants.
//!
//! Shape claim: QRazor wins the W4A4 comparison on the GQA model too —
//! the "reliability across architectures" argument.

use qrazor::baselines::awq::AwqScheme;
use qrazor::baselines::smoothquant::SmoothQuantScheme;
use qrazor::baselines::QRazor;
use qrazor::eval::harness::{build_experiment, render_table, EvalScale};

fn main() -> anyhow::Result<()> {
    let scale = EvalScale::from_env();
    let preset = std::env::var("BENCH_MODELS").unwrap_or_else(|_| "mistral-tiny".into());
    for preset in preset.split(',') {
        let exp = build_experiment(preset.trim(), scale, 1)?;
        let rows = vec![
            exp.eval_fp(),
            exp.eval_scheme(Box::new(SmoothQuantScheme::w4a4(0.5))),
            exp.eval_scheme(Box::new(SmoothQuantScheme::w4a4(0.75))), // OS+-class
            exp.eval_scheme(Box::new(AwqScheme::w4a4(128))),
            exp.eval_scheme(Box::new(QRazor::w4a4(16))),
            exp.eval_scheme(Box::new(QRazor::w4a4(32))),
            exp.eval_scheme(Box::new(QRazor::w4a4kv4(16))),
            exp.eval_scheme(Box::new(QRazor::w4a4kv4(32))),
        ];
        println!(
            "{}",
            render_table(&format!("Table 10 — GQA architecture ({preset})"), &rows)
        );
        let qrazor = rows.iter().find(|r| r.name == "QRazor-W4A4 g16").unwrap();
        for baseline in &rows[1..4] {
            assert!(
                qrazor.ppl_wiki < baseline.ppl_wiki,
                "QRazor ppl {} must beat {} ({})",
                qrazor.ppl_wiki,
                baseline.name,
                baseline.ppl_wiki
            );
        }
    }
    Ok(())
}
