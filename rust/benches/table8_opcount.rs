//! Table 8 (Appendix A.4) — quantization-overhead operation counts:
//! QuaRot's Hadamard rotations (FLOPs) vs QRazor's SDR compression +
//! barrel shifts (IOPs), at the paper's dimensions and across a sweep.
//! Also *measures* the two code paths' wall-clock on this machine.

use qrazor::hw::opcount::{hadamard_fwht, table8_rows, OpKind};
use qrazor::util::stats::bench_loop;

fn main() {
    println!("\n=== Table 8 — op counts (M=128, N=64, H=8, G=32) ===");
    let rows = table8_rows(128, 64, 8, 32);
    println!("{:<18} {:<16} {:>10} {:>6}", "operation", "formula", "count", "kind");
    for r in &rows {
        println!(
            "{:<18} {:<16} {:>10} {:>6}",
            r.operation,
            r.formula,
            r.count,
            match r.kind {
                OpKind::Flop => "FLOPs",
                OpKind::Iop => "IOPs",
            }
        );
    }
    assert_eq!(rows[0].count, 8_192);
    assert_eq!(rows[1].count, 65_536);
    assert_eq!(rows[2].count, 512);
    assert_eq!(rows[3].count, 256);

    println!(
        "\nextension: fast-WHT (N log N) Hadamard = {} FLOPs — still ≫ SDR",
        hadamard_fwht(128, 64)
    );

    println!("\nsweep over group size (SDR ops, M=128 N=64):");
    for g in [8u64, 16, 32, 64, 128] {
        let r = table8_rows(128, 64, 8, g);
        println!("  g{:<4} compression {:>6} + shifts {:>6}", g, r[2].count, r[3].count);
    }

    // measured wall-clock of the actual implementations
    use qrazor::baselines::quarot::rotate_rows;
    use qrazor::quant::{Granularity, QuantTensor};
    use qrazor::sdr::{SdrMatrix, SdrSpec};
    use qrazor::tensor::Tensor;
    use qrazor::util::rng::Rng;
    let mut rng = Rng::new(1);
    let mut x = Tensor::zeros(&[128, 64]);
    rng.fill_normal(x.data_mut(), 0.0, 1.0);
    let q = QuantTensor::quantize(&x, 16, Granularity::PerTensor);
    let rot = bench_loop(5, 50, || std::hint::black_box(rotate_rows(&x, 3)));
    let sdr = bench_loop(5, 50, || {
        std::hint::black_box(SdrMatrix::compress(SdrSpec::new(16, 4, 32), &q))
    });
    println!("\nmeasured on this machine (128×64):");
    println!("  hadamard rotate : {}", rot.human());
    println!("  SDR compress    : {}", sdr.human());
    println!("table8 OK");
}
