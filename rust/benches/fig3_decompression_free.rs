//! Figure 3 — decompression-free arithmetic (b) vs decompress-then-
//! multiply (a): bit-exact equivalence at every size, plus measured
//! speed and the Fig. 4 encoder-datapath co-simulation.

use qrazor::hw::datapath::{encode_group, MacUnit};
use qrazor::quant::{Granularity, QuantTensor};
use qrazor::sdr::gemm::{gemm_decompress, gemm_razored_int, gemm_razored_packed};
use qrazor::sdr::packed::PackedSdrMatrix;
use qrazor::sdr::razor::{compress_group, SdrCode};
use qrazor::sdr::{SdrMatrix, SdrSpec};
use qrazor::tensor::Tensor;
use qrazor::util::rng::Rng;
use qrazor::util::stats::bench_loop;

fn make_pair(m: usize, n: usize, k: usize, g: usize, seed: u64) -> (SdrMatrix, SdrMatrix) {
    let mut rng = Rng::new(seed);
    let mut x = Tensor::zeros(&[m, k]);
    for v in x.data_mut().iter_mut() {
        *v = rng.heavy_tailed(1.0, 0.02, 20.0);
    }
    let mut wt = Tensor::zeros(&[n, k]);
    rng.fill_normal(wt.data_mut(), 0.0, 0.05);
    (
        SdrMatrix::compress(
            SdrSpec::new(16, 4, g),
            &QuantTensor::quantize(&x, 16, Granularity::PerTensor),
        ),
        SdrMatrix::compress(
            SdrSpec::new(8, 4, g),
            &QuantTensor::quantize(&wt, 8, Granularity::PerChannel),
        ),
    )
}

fn main() {
    println!("\n=== Fig. 3 — decompression-free vs decompressed GEMM ===");
    // exact equivalence across a size sweep — packed, unpacked, reference
    for (m, n, k, g) in [(4, 8, 64, 16), (16, 16, 256, 32), (32, 64, 512, 16)] {
        let (a, w) = make_pair(m, n, k, g, (m * n) as u64);
        let (pa, pw) = (PackedSdrMatrix::from_matrix(&a), PackedSdrMatrix::from_matrix(&w));
        let reference = gemm_decompress(&a, &w);
        assert_eq!(gemm_razored_int(&a, &w).data(), reference.data(), "{m}x{n}x{k} g{g}");
        let packed = gemm_razored_packed(&pa, &pw);
        assert_eq!(packed.data(), reference.data(), "{m}x{n}x{k} g{g} packed");
        println!("  {m:>3}×{n:<3} k={k:<4} g{g:<3}: packed ≡ unpacked ≡ decompressed ✓");
    }

    // measured speed of the three software paths + operand bytes moved
    let (a, w) = make_pair(32, 64, 512, 16, 9);
    let (pa, pw) = (PackedSdrMatrix::from_matrix(&a), PackedSdrMatrix::from_matrix(&w));
    let razored = bench_loop(3, 20, || std::hint::black_box(gemm_razored_int(&a, &w)));
    let packed = bench_loop(3, 20, || std::hint::black_box(gemm_razored_packed(&pa, &pw)));
    let decomp = bench_loop(3, 20, || std::hint::black_box(gemm_decompress(&a, &w)));
    println!("\nmeasured (32×64, k=512, g16):");
    println!("  razored (unpacked): {}", razored.human());
    println!("  razored (packed)  : {}", packed.human());
    println!("  decompress        : {}", decomp.human());
    let packed_bytes = pa.payload_bytes() + pw.payload_bytes();
    let unpacked_bytes = pa.unpacked_payload_bytes() + pw.unpacked_payload_bytes();
    let ratio = packed_bytes as f64 / unpacked_bytes as f64;
    println!(
        "operand bytes: packed {} vs unpacked {} ({:.1}% — {:.3} vs {:.3} bits/value)",
        packed_bytes,
        unpacked_bytes,
        100.0 * ratio,
        pa.measured_effective_bits(),
        8.0 * unpacked_bytes as f64 / ((pa.rows * pa.cols + pw.rows * pw.cols) as f64),
    );
    assert!(ratio <= 0.55, "packed operands must move ≤55% of unpacked bytes: {ratio}");

    // Fig. 4: encoder datapath == software coder on random groups
    let spec = SdrSpec::new(16, 4, 16);
    let mut rng = Rng::new(11);
    for _ in 0..200 {
        let vals: Vec<i32> = (0..16).map(|_| rng.range_i64(-32767, 32767) as i32).collect();
        let signs: Vec<bool> = vals.iter().map(|&v| v < 0).collect();
        let mags: Vec<u16> = vals.iter().map(|&v| v.unsigned_abs() as u16).collect();
        let (hw_flag, hw_codes) = encode_group(&spec, &signs, &mags);
        let mut sw = vec![SdrCode::default(); 16];
        let sw_flag = compress_group(&spec, &vals, &mut sw);
        assert_eq!((hw_flag, &hw_codes), (sw_flag, &sw));
    }
    println!("Fig. 4 encoder datapath ≡ Algorithm 1 coder over 200 random groups ✓");

    // MAC-unit lane equivalence (the hardware's per-cycle behavior)
    let mut razored_mac = MacUnit::new();
    let mut reference_mac = MacUnit::new();
    for _ in 0..10_000 {
        let a = SdrCode { neg: rng.chance(0.5), code: rng.below(8) as u8 };
        let b = SdrCode { neg: rng.chance(0.5), code: rng.below(8) as u8 };
        let (fa, fb) = (rng.below(13) as u8, rng.below(5) as u8);
        razored_mac.mac(a, b, fa, fb, 3);
        reference_mac.mac_decompressed(a, b, fa, fb);
    }
    assert_eq!(razored_mac.acc, reference_mac.acc);
    println!("MAC lane ≡ decompressed MAC over 10k cycles ✓ (acc {})", razored_mac.acc);
    println!("fig3 OK");
}
