//! Table 6 (Appendix A.1) — weight-vs-activation compression
//! sensitivity from the same W8A16 base, group size 8:
//! W4A8 vs W8A8 vs W4A16.
//!
//! Shape claim: W8A8 is the best of the three (weight compression to 4
//! bits costs at least as much as activation compression to 8).

use qrazor::baselines::QRazor;
use qrazor::eval::harness::{build_experiment, render_table, EvalScale};

fn main() -> anyhow::Result<()> {
    let scale = EvalScale::from_env();
    let preset = std::env::var("BENCH_MODELS").unwrap_or_else(|_| "tiny".into());
    for preset in preset.split(',') {
        let exp = build_experiment(preset.trim(), scale, 1)?;
        let rows = vec![
            exp.eval_fp(),
            exp.eval_scheme(Box::new(QRazor::ablation(4, 8, 8))),  // W4A8
            exp.eval_scheme(Box::new(QRazor::ablation(8, 8, 8))),  // W8A8
            exp.eval_scheme(Box::new(QRazor::ablation(4, 16, 8))), // W4A16
        ];
        println!(
            "{}",
            render_table(&format!("Table 6 — weight sensitivity, g8 ({preset})"), &rows)
        );
        let (w4a8, w8a8, w4a16) = (&rows[1], &rows[2], &rows[3]);
        assert!(
            w8a8.ppl_wiki <= w4a8.ppl_wiki * 1.02 && w8a8.ppl_wiki <= w4a16.ppl_wiki * 1.02,
            "W8A8 ({}) must be best of {{W4A8 {}, W4A16 {}}}",
            w8a8.ppl_wiki,
            w4a8.ppl_wiki,
            w4a16.ppl_wiki
        );
    }
    Ok(())
}
