//! Table 3 — W4A8 ablation: QRazor W4A8 / W4A8KV4 (g16, g32) vs the
//! QLLM-W4A8 and QServe-W4A8KV4 comparators.
//!
//! Shape claims: W4A8 recovers most of the FP gap (≪ W4A4 degradation);
//! QRazor ≳ QLLM and ≈ QServe.

use qrazor::baselines::qllm::QllmScheme;
use qrazor::baselines::qserve::QServeScheme;
use qrazor::baselines::QRazor;
use qrazor::eval::harness::{build_experiment, render_table, EvalScale};

fn main() -> anyhow::Result<()> {
    let scale = EvalScale::from_env();
    let preset = std::env::var("BENCH_MODELS").unwrap_or_else(|_| "tiny".into());
    for preset in preset.split(',') {
        let exp = build_experiment(preset.trim(), scale, 1)?;
        let rows = vec![
            exp.eval_fp(),
            exp.eval_scheme(Box::new(QllmScheme::w4a8())),
            exp.eval_scheme(Box::new(QServeScheme::w4a8kv4(128))),
            exp.eval_scheme(Box::new(QRazor::w4a8(16))),
            exp.eval_scheme(Box::new(QRazor::w4a8(32))),
            exp.eval_scheme(Box::new(QRazor::w4a8kv4(16))),
            exp.eval_scheme(Box::new(QRazor::w4a8kv4(32))),
            // contrast row: W4A4 to show A8's recovery
            exp.eval_scheme(Box::new(QRazor::w4a4(16))),
        ];
        println!("{}", render_table(&format!("Table 3 — W4A8 ({preset})"), &rows));
        let fp = &rows[0];
        let a8 = rows.iter().find(|r| r.name == "QRazor-W4A8 g16").unwrap();
        let a4 = rows.iter().find(|r| r.name == "QRazor-W4A4 g16").unwrap();
        assert!(
            (a8.ppl_wiki - fp.ppl_wiki) <= (a4.ppl_wiki - fp.ppl_wiki) + 1e-9,
            "A8 gap must not exceed A4 gap"
        );
        assert!(
            (a8.ppl_wiki - fp.ppl_wiki) / fp.ppl_wiki < 0.10,
            "W4A8 should land within 10% of FP ppl (got {} vs {})",
            a8.ppl_wiki,
            fp.ppl_wiki
        );
    }
    Ok(())
}
