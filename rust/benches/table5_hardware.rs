//! Table 5 — MAC-unit area/power: FP16×16, INT16×8, INT8×8 vs the
//! proposed INT4×4 + barrel-shifter unit, from the unit-gate cost model
//! calibrated at 65nm LP, printed next to the paper's synthesis values.

use qrazor::hw::cost::{saving_pct, table5_designs, table5_paper_reference};

fn main() {
    println!("\n=== Table 5 — MAC unit area/power (model vs paper) ===");
    println!(
        "{:<18} | {:>10} {:>10} {:>7} | {:>10} {:>10} {:>7}",
        "design", "area µm²", "paper", "Δ%", "power mW", "paper", "Δ%"
    );
    let designs = table5_designs();
    let paper = table5_paper_reference();
    for (d, (_, pa, pp)) in designs.iter().zip(&paper) {
        println!(
            "{:<18} | {:>10.1} {:>10.1} {:>6.1}% | {:>10.4} {:>10.4} {:>6.1}%",
            d.name,
            d.area_um2(),
            pa,
            100.0 * (d.area_um2() / pa - 1.0),
            d.power_mw(),
            pp,
            100.0 * (d.power_mw() / pp - 1.0),
        );
        // block breakdown, as the paper reports
        println!(
            "{:<18} |   mult {:>7.1}µm²  shift {:>7.1}µm²  reg+accm {:>7.1}µm²",
            "",
            d.multiplier.area_um2(),
            d.shifter.as_ref().map(|b| b.area_um2()).unwrap_or(0.0),
            d.reg_accum.area_um2()
        );
    }
    let a_save = saving_pct(designs[1].area_um2(), designs[3].area_um2());
    let p_save = saving_pct(designs[1].power_mw(), designs[3].power_mw());
    let a_save8 = saving_pct(designs[2].area_um2(), designs[3].area_um2());
    let p_save8 = saving_pct(designs[2].power_mw(), designs[3].power_mw());
    println!(
        "\nproposed vs INT16x8 : area -{a_save:.1}% (paper -61.2%), \
         power -{p_save:.1}% (paper -56%)"
    );
    println!(
        "proposed vs INT8x8  : area -{a_save8:.1}% (paper -34%),  \
         power -{p_save8:.1}% (paper -33.7%)"
    );
    assert!((50.0..72.0).contains(&a_save));
    assert!((45.0..68.0).contains(&p_save));
    assert!((22.0..46.0).contains(&a_save8));
    assert!((20.0..48.0).contains(&p_save8));
    println!("table5 OK");
}
