//! Table 1 — zero-shot accuracy of the base precision scenarios.
//!
//! Paper claim (shape): W8A16 and W8A16KV8 sit within noise of FP16;
//! W8A8 collapses. Regenerates the table for our trained models.
//!
//! ```bash
//! cargo bench --bench table1_base_precision
//! QRAZOR_BENCH_QUICK=1 cargo bench ...   # CI scale
//! BENCH_MODELS=nano,tiny cargo bench ... # model selection
//! ```

use qrazor::baselines::QRazor;
use qrazor::eval::harness::{build_experiment, render_table, EvalScale};
use qrazor::sdr::SdrSpec;

fn models() -> Vec<String> {
    std::env::var("BENCH_MODELS")
        .unwrap_or_else(|_| "tiny".into())
        .split(',')
        .map(|s| s.trim().to_string())
        .collect()
}

fn main() -> anyhow::Result<()> {
    let scale = EvalScale::from_env();
    for preset in models() {
        let exp = build_experiment(&preset, scale, 1)?;
        // base-precision-only scenarios: target == base, no SDR stage.
        let w8a8 = QRazor {
            w: SdrSpec::new(8, 8, 16),
            a: SdrSpec::new(8, 8, 16),
            kv_spec: None,
        };
        let w8a16 = QRazor {
            w: SdrSpec::new(8, 8, 16),
            a: SdrSpec::new(16, 16, 16),
            kv_spec: None,
        };
        let w8a16kv8 = QRazor {
            w: SdrSpec::new(8, 8, 16),
            a: SdrSpec::new(16, 16, 16),
            kv_spec: Some(SdrSpec::new(8, 8, 16)),
        };
        let rows = vec![
            exp.eval_fp(),
            exp.eval_scheme(Box::new(w8a8)),
            exp.eval_scheme(Box::new(w8a16)),
            exp.eval_scheme(Box::new(w8a16kv8)),
        ];
        println!("{}", render_table(&format!("Table 1 — base precision ({preset})"), &rows));
        // the paper's ordering, asserted so regressions fail the bench
        let (fp, a8, a16, a16kv8) = (&rows[0], &rows[1], &rows[2], &rows[3]);
        assert!(
            a16.ppl_wiki <= a8.ppl_wiki + 1e-6,
            "W8A16 ppl {} must not exceed W8A8 {}",
            a16.ppl_wiki,
            a8.ppl_wiki
        );
        assert!(
            (a16.ppl_wiki - fp.ppl_wiki).abs() / fp.ppl_wiki < 0.05,
            "W8A16 must sit within 5% of FP (got {} vs {})",
            a16.ppl_wiki,
            fp.ppl_wiki
        );
        assert!((a16kv8.ppl_wiki - fp.ppl_wiki).abs() / fp.ppl_wiki < 0.05);
    }
    Ok(())
}
