//! Table 9 (Appendix A.5) — the full grid: zero-shot average accuracy
//! for {W4A8, W4A4, W4A8KV4, W4A4KV4} × g ∈ {8,16,32,64,128}.
//!
//! Shape claims: accuracy weakly decreases down each column (group
//! size) and W4A8 ≥ W4A4 / W4A8KV4 ≥ W4A4KV4 row-family ordering holds
//! on average.

use qrazor::baselines::QRazor;
use qrazor::eval::harness::{build_experiment, EvalScale};
use qrazor::eval::perplexity::perplexity;
use qrazor::model::quantized::QuantModel;

fn main() -> anyhow::Result<()> {
    let scale = EvalScale::from_env();
    let preset = std::env::var("BENCH_MODELS").unwrap_or_else(|_| "tiny".into());
    for preset in preset.split(',') {
        let exp = build_experiment(preset.trim(), scale, 1)?;
        let fp = qrazor::model::FpModel { weights: exp.weights.clone() };
        let fp_ppl = perplexity(&fp, &exp.wiki_seqs);
        println!("\n=== Table 9 — full sweep ({preset}) ===");
        println!("FP16 baseline wiki ppl {fp_ppl:.3}");
        println!("(ppl-only grid: the zero-shot columns are chance-level noise");
        println!(" at this model scale — see EXPERIMENTS.md conventions)");
        println!("{:<10} {:>6} {:>10}", "config", "g", "wiki ppl");
        let groups = [8usize, 16, 32, 64, 128];
        let mut fam_ppl: Vec<(String, f64)> = Vec::new();
        for (name, mk) in [
            ("W4A8", Box::new(QRazor::w4a8) as Box<dyn Fn(usize) -> QRazor>),
            ("W4A4", Box::new(QRazor::w4a4)),
            ("W4A8KV4", Box::new(QRazor::w4a8kv4)),
            ("W4A4KV4", Box::new(QRazor::w4a4kv4)),
        ] {
            let mut mean_ppl = 0.0;
            let mut prev_ppl = 0.0;
            for &g in &groups {
                let qm = QuantModel::build(&exp.weights, Box::new(mk(g)), &exp.cal);
                let ppl = perplexity(&qm, &exp.wiki_seqs);
                println!("{:<10} {:>6} {:>10.3}", name, g, ppl);
                assert!(
                    g == groups[0] || ppl * 1.08 >= prev_ppl,
                    "{name} g{g}: ppl should not improve with larger groups"
                );
                prev_ppl = ppl;
                mean_ppl += ppl / groups.len() as f64;
            }
            fam_ppl.push((name.to_string(), mean_ppl));
        }
        // family ordering on mean ppl: A8 ≤ A4 within matching KV config
        let get = |n: &str| fam_ppl.iter().find(|(k, _)| k == n).unwrap().1;
        assert!(get("W4A8") <= get("W4A4") * 1.02, "W4A8 must beat W4A4 on mean ppl");
        assert!(
            get("W4A8KV4") <= get("W4A4KV4") * 1.02,
            "W4A8KV4 must beat W4A4KV4 on mean ppl"
        );
    }
    Ok(())
}
