//! Table 2 — the paper's headline comparison: QRazor W4A4 / W4A4KV4
//! (g16, g32) vs the baseline families (SmoothQuant/OS+-class, QLLM,
//! QuaRot(RTN), QuaRot(GPTQ)) plus FP16, on perplexity and the
//! zero-shot suite.
//!
//! Shape claims checked: QRazor > {SmoothQuant, QLLM, QuaRot(RTN)} and
//! ≈ QuaRot(GPTQ); g16 ≥ g32.

use qrazor::baselines::qllm::QllmScheme;
use qrazor::baselines::quarot::QuaRotScheme;
use qrazor::baselines::rtn::RtnScheme;
use qrazor::baselines::smoothquant::SmoothQuantScheme;
use qrazor::baselines::QRazor;
use qrazor::eval::harness::{build_experiment, render_table, EvalScale};

fn models() -> Vec<String> {
    std::env::var("BENCH_MODELS")
        .unwrap_or_else(|_| "tiny".into())
        .split(',')
        .map(|s| s.trim().to_string())
        .collect()
}

fn main() -> anyhow::Result<()> {
    let scale = EvalScale::from_env();
    for preset in models() {
        let exp = build_experiment(&preset, scale, 1)?;
        let rows = vec![
            exp.eval_fp(),
            exp.eval_scheme(Box::new(SmoothQuantScheme::w4a4(0.5))),
            exp.eval_scheme(Box::new(QllmScheme::w4a4())),
            exp.eval_scheme(Box::new(RtnScheme::w4a4kv4(128))),
            exp.eval_scheme(Box::new(QuaRotScheme::rtn_w4a4kv4())),
            exp.eval_scheme(Box::new(QuaRotScheme::gptq_w4a4kv4())),
            exp.eval_scheme(Box::new(QRazor::w4a4(16))),
            exp.eval_scheme(Box::new(QRazor::w4a4(32))),
            exp.eval_scheme(Box::new(QRazor::w4a4kv4(16))),
            exp.eval_scheme(Box::new(QRazor::w4a4kv4(32))),
        ];
        println!("{}", render_table(&format!("Table 2 — W4A4 main results ({preset})"), &rows));

        let by_name = |needle: &str| {
            rows.iter()
                .find(|r| r.name.contains(needle))
                .unwrap_or_else(|| panic!("row {needle}"))
        };
        let qrazor16 = by_name("QRazor-W4A4 g16");
        let smooth = by_name("SmoothQuant");
        let qllm = by_name("QLLM");
        // headline: QRazor beats the migration/splitting baselines at W4A4
        assert!(
            qrazor16.ppl_wiki < smooth.ppl_wiki,
            "QRazor ppl {} must beat SmoothQuant {}",
            qrazor16.ppl_wiki,
            smooth.ppl_wiki
        );
        assert!(
            qrazor16.ppl_wiki < qllm.ppl_wiki * 1.2,
            "QRazor ppl {} should be at least comparable to QLLM {}",
            qrazor16.ppl_wiki,
            qllm.ppl_wiki
        );
        // group-size monotonicity within QRazor
        let g32 = by_name("QRazor-W4A4 g32");
        assert!(
            qrazor16.ppl_wiki <= g32.ppl_wiki * 1.05,
            "g16 ppl {} should not exceed g32 {}",
            qrazor16.ppl_wiki,
            g32.ppl_wiki
        );
    }
    Ok(())
}
