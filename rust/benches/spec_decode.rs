//! Speculative decoding: acceptance rate vs single-stream tok/s.
//!
//! Two sections:
//!
//! 1. **Real models** — the nano QuantModel pair (draft = packed
//!    W4A4KV4, verify = W4A8KV4 basis, same weights + calibration).
//!    Sweeps the lookahead `k`, asserting the committed stream is
//!    token-identical to plain decode and reporting measured
//!    acceptance and tok/s. A draft==target point pins acceptance at
//!    exactly 1.0 (the chunk ≡ sequential identity).
//!
//! 2. **Synthetic datapath sweep** — the speculative harness driven by
//!    [`SpecLm`] cost models whose per-forward work is calibrated to
//!    the repo's own Table 5 MAC designs: the proposed SDR 4×4 draft
//!    datapath costs ~0.44× the INT16×8 basis MAC (power ratio,
//!    `hw::cost::table5_designs`), and a batched verify chunk streams
//!    the weight operand once, so each extra verify row only pays the
//!    MAC marginal. Sweeping the draft agreement rate maps acceptance
//!    to throughput; at high acceptance the sweep must show ≥1.3×
//!    single-stream tok/s over non-speculative decode — the paper-
//!    hardware shape of the W4A4-vs-W4A8 gap turned into serving
//!    speed. (The scalar CPU kernels in this repo execute A4 and A8
//!    MACs at the same speed, so the real-model section reports its
//!    measured ratio without asserting it.)
//!
//! `--smoke` runs a reduced sweep (CI).

use std::sync::Arc;

use qrazor::baselines::QRazor;
use qrazor::config::{ModelConfig, ServeConfig};
use qrazor::coordinator::{collect_sessions, Sampling, ServeApi, Server};
use qrazor::hw::cost::table5_designs;
use qrazor::model::quantized::{calibrate, QuantModel};
use qrazor::model::ModelWeights;
use qrazor::spec::{SpecDecoder, SpecLm, SpecStats};
use qrazor::util::rng::Rng;

// ---------------------------------------------------------------- real

fn build_pair() -> (Arc<QuantModel>, Arc<QuantModel>) {
    let cfg = ModelConfig::preset("nano").unwrap();
    let w = ModelWeights::init_random(&cfg, 3);
    let mut rng = Rng::new(4);
    let seqs: Vec<Vec<u32>> = (0..2)
        .map(|_| (0..32).map(|_| rng.below(cfg.vocab as u64) as u32).collect())
        .collect();
    let cal = calibrate(&w, &seqs);
    let target = Arc::new(QuantModel::build(&w, Box::new(QRazor::w4a8kv4(16)), &cal));
    let draft = Arc::new(QuantModel::build(&w, Box::new(QRazor::w4a4kv4(16)), &cal));
    (target, draft)
}

/// One greedy session through any [`ServeApi`] front-end, streamed;
/// returns (stream, tok/s, acceptance, rollbacks). The speculative
/// accounting comes from the live stats snapshot, and the streamed
/// `Token` payloads are asserted identical to the final response —
/// with speculation on, accepted prefixes arrive as multi-token
/// batches.
fn single_stream(api: &impl ServeApi, max_new: usize) -> (Vec<u32>, f64, f64, u64) {
    let prompt: Vec<u32> = vec![5, 9, 2, 7, 1, 4, 8, 3];
    let t0 = std::time::Instant::now();
    api.submit(prompt, max_new, Sampling::Greedy).expect("submit");
    let sessions = collect_sessions(api, 1).expect("stream");
    let dt = t0.elapsed().as_secs_f64();
    let log = sessions.values().next().expect("one session");
    let resp = log.response.clone().expect("finished");
    assert_eq!(log.tokens(), resp.tokens, "streamed ≡ batch");
    let s = api.stats().spec;
    (resp.tokens, max_new as f64 / dt, s.acceptance(), s.rejected)
}

// ----------------------------------------------------------- synthetic

/// Deterministic "true" next token at a position.
fn true_next(seed: u64, pos: usize) -> u32 {
    let mut x = seed ^ (pos as u64).wrapping_mul(0x9E3779B97F4A7C15);
    x ^= x >> 30;
    x = x.wrapping_mul(0xBF58476D1CE4E5B9);
    x ^= x >> 27;
    (x % SYNTH_VOCAB as u64) as u32
}

const SYNTH_VOCAB: u32 = 64;

/// Real arithmetic work standing in for one unit of datapath cost.
fn burn(units: usize) -> f32 {
    let mut acc = 0.1f32;
    for i in 0..units * 64 {
        acc = acc.mul_add(0.999_99, (i as f32) * 1e-9);
    }
    std::hint::black_box(acc)
}

/// A cost-model language model: deterministic greedy choices, tunable
/// per-forward work, and (for the draft role) a tunable agreement rate
/// with the target's choices.
struct SynthLm {
    tokens: usize,
    seed: u64,
    /// Work units per single-token forward.
    token_work: usize,
    /// Fixed units per chunk (weight stream + dispatch) + marginal
    /// units per chunk row (MACs only).
    chunk_fixed: usize,
    chunk_row: usize,
    /// Percentage of positions where this model's argmax equals the
    /// true next token (the target runs at 100).
    agree_pct: u64,
    /// Deterministic cost-model units burned so far — what the CI
    /// speedup gate asserts on (wall clock is reported, not gated).
    units: u64,
}

impl SynthLm {
    fn new(seed: u64, token_work: usize, chunk_fixed: usize, chunk_row: usize, agree: u64) -> Self {
        SynthLm { tokens: 0, seed, token_work, chunk_fixed, chunk_row, agree_pct: agree, units: 0 }
    }

    fn choice(&self, pos: usize) -> u32 {
        let t = true_next(self.seed, pos);
        let h = true_next(self.seed ^ 0xA5A5_A5A5, pos) as u64 * 97 % 100;
        if h < self.agree_pct {
            t
        } else {
            (t + 1) % SYNTH_VOCAB
        }
    }

    fn one_hot(&self, tok: u32) -> Vec<f32> {
        let mut v = vec![0f32; SYNTH_VOCAB as usize];
        v[tok as usize] = 1.0;
        v
    }
}

impl SpecLm for SynthLm {
    fn cached_tokens(&self) -> usize {
        self.tokens
    }
    fn forward_token(&mut self, _token: u32, pos: usize) -> Vec<f32> {
        assert_eq!(pos, self.tokens, "synthetic cache out of sync");
        self.units += self.token_work as u64;
        let _ = burn(self.token_work);
        self.tokens += 1;
        self.one_hot(self.choice(pos))
    }
    fn forward_chunk(&mut self, tokens: &[u32], start_pos: usize) -> Vec<Vec<f32>> {
        assert_eq!(start_pos, self.tokens, "synthetic cache out of sync");
        let work = self.chunk_fixed + tokens.len() * self.chunk_row;
        self.units += work as u64;
        let _ = burn(work);
        self.tokens += tokens.len();
        (0..tokens.len()).map(|i| self.one_hot(self.choice(start_pos + i))).collect()
    }
    fn truncate(&mut self, tokens: usize) {
        self.tokens = self.tokens.min(tokens);
    }
}

fn main() {
    let smoke = std::env::args().any(|a| a == "--smoke");
    let (real_new, synth_new) = if smoke { (12usize, 400usize) } else { (48, 2000) };
    // Stage + hot-path timing on for the whole run: the registry
    // snapshot at the end carries spec_draft/spec_verify aggregates.
    qrazor::obs::set_timing(true);
    qrazor::obs::hot_reset();

    // ---- section 1: real models -------------------------------------
    println!("=== speculative decode, real models (nano, draft W4A4KV4 -> verify W4A8KV4) ===");
    println!(
        "{:<26} {:>4} {:>10} {:>10} {:>10}",
        "config", "k", "tok/s", "accept", "rollbacks"
    );
    let (target, draft) = build_pair();
    let plain = Server::spawn(
        Arc::clone(&target),
        ServeConfig { max_batch: 1, max_new_tokens: real_new, ..Default::default() },
    );
    let (want, base_tps, _, _) = single_stream(&plain, real_new);
    plain.shutdown();
    println!("{:<26} {:>4} {:>10.1} {:>10} {:>10}", "plain (no draft)", "-", base_tps, "-", "-");
    for k in [0usize, 2, 4] {
        let server = Server::spawn_with_draft(
            Arc::clone(&target),
            Some(Arc::clone(&draft)),
            ServeConfig { max_batch: 1, max_new_tokens: real_new, spec_k: k, ..Default::default() },
        );
        let (got, tps, accept, rollbacks) = single_stream(&server, real_new);
        server.shutdown();
        assert_eq!(got, want, "k={k}: speculative stream diverged from plain decode");
        println!(
            "{:<26} {:>4} {:>10.1} {:>9.0}% {:>10}",
            "spec (W4A4 draft)", k, tps, accept * 100.0, rollbacks
        );
    }
    // draft == target: acceptance is exactly 1.0 by the chunk identity
    let server = Server::spawn_with_draft(
        Arc::clone(&target),
        Some(Arc::clone(&target)),
        ServeConfig { max_batch: 1, max_new_tokens: real_new, spec_k: 4, ..Default::default() },
    );
    let (got, tps, accept, rollbacks) = single_stream(&server, real_new);
    let self_draft_metrics = server.shutdown_with_metrics().expect("serve worker");
    assert_eq!(got, want, "self-draft stream diverged");
    assert!(
        (accept - 1.0).abs() < 1e-12,
        "draft==target must accept every proposal, got {accept}"
    );
    println!(
        "{:<26} {:>4} {:>10.1} {:>9.0}% {:>10}",
        "spec (self-draft)", 4, tps, accept * 100.0, rollbacks
    );

    // ---- section 2: synthetic Table-5 datapath sweep ----------------
    // Datapath cost ratio from the repo's own unit-gate MAC models:
    // the proposed SDR 4x4 draft unit vs the INT16x8 basis MAC
    // (power), the W4A4-vs-basis gap of the paper's Table 5. A verify
    // chunk streams the basis weights once (1.0x a token forward) and
    // each extra row pays only the MAC marginal (0.1x) — the
    // memory-bound decode shape batched verification amortizes. Each
    // model's chunk costs scale with its own datapath ratio.
    let designs = table5_designs();
    let draft_ratio = designs[3].power_mw() / designs[1].power_mw(); // ~0.44
    const TARGET_WORK: usize = 300;
    let scaled = |r: f64| -> (usize, usize, usize) {
        let token = (TARGET_WORK as f64 * r).round() as usize;
        (token, token, token / 10) // (token, chunk_fixed, chunk_row)
    };
    let (t_tok, t_fixed, t_row) = scaled(1.0);
    let (d_tok, d_fixed, d_row) = scaled(draft_ratio);
    println!(
        "\n=== synthetic datapath sweep (Table 5 cost model: draft {draft_ratio:.2}x the \
         basis MAC, verify chunk 1.0x + 0.1x/row) ===",
    );
    println!(
        "{:>4} {:>8} {:>12} {:>12} {:>10} {:>10} {:>9}",
        "k", "agree%", "base tok/s", "spec tok/s", "wall x", "units x", "accept"
    );
    // baseline: target-only decode at the same cost model
    let base_tps = {
        let mut t = SynthLm::new(7, t_tok, t_fixed, t_row, 100);
        let mut tok = 0u32;
        let t0 = std::time::Instant::now();
        for pos in 0..synth_new {
            let logits = t.forward_token(tok, pos);
            tok = qrazor::tensor::argmax(&logits) as u32;
        }
        synth_new as f64 / t0.elapsed().as_secs_f64()
    };
    let want: Vec<u32> = {
        // the deterministic target stream every sweep point must emit
        let t = SynthLm::new(7, 0, 0, 0, 100);
        (0..synth_new).map(|pos| t.choice(pos)).collect()
    };
    // Returns the *deterministic* unit-cost speedup (baseline datapath
    // units per token over speculative units per token) — the gated
    // number; wall clock is printed alongside but never asserted, so
    // a noisy CI runner cannot flake the job.
    let run_point = |k: usize, agree: u64| -> f64 {
        let mut target = SynthLm::new(7, t_tok, t_fixed, t_row, 100);
        let mut draft = SynthLm::new(7, d_tok, d_fixed, d_row, agree);
        let mut stats = SpecStats::default();
        let t0 = std::time::Instant::now();
        let got =
            SpecDecoder::new(k).generate(&[0], &mut draft, &mut target, synth_new, &mut stats);
        let tps = synth_new as f64 / t0.elapsed().as_secs_f64();
        assert_eq!(got, want, "k={k} agree {agree}%: stream diverged from target-only decode");
        let base_units = (synth_new * t_tok) as f64;
        let unit_speedup = base_units / (target.units + draft.units) as f64;
        println!(
            "{:>4} {:>8} {:>12.1} {:>12.1} {:>9.2}x {:>9.2}x {:>8.0}%",
            k,
            agree,
            base_tps,
            tps,
            tps / base_tps,
            unit_speedup,
            stats.acceptance() * 100.0
        );
        unit_speedup
    };
    // acceptance axis at a fixed lookahead
    for agree in [50u64, 80, 95, 100] {
        run_point(4, agree);
    }
    // lookahead axis at full acceptance; the deeper points are the
    // high-acceptance headline (expected ~1.45x at k=6 under this
    // cost model: 7 tokens for ~0.44·6 + 1.7 ≈ 4.3 token-equivalents)
    let mut best = 0.0f64;
    for k in [2usize, 4, 6] {
        best = best.max(run_point(k, 100));
    }
    assert!(
        best >= 1.3,
        "high-acceptance speculative decode must reach >=1.3x under the Table-5 cost \
         model, got {best:.2}x"
    );

    // ---- registry snapshot: the self-draft serve run's metrics plus
    // the global hot-path aggregates (spec_draft/spec_verify/packed
    // attention), schema-checked in smoke mode.
    let mut reg = self_draft_metrics.to_registry(&[("bench", "spec_decode")]);
    qrazor::obs::export_hot(&mut reg);
    let json = reg.to_json().to_string();
    std::fs::write("BENCH_spec_decode.json", &json).expect("write BENCH_spec_decode.json");
    println!("registry snapshot -> BENCH_spec_decode.json");
    if smoke {
        let parsed = qrazor::util::json::Json::parse(&json).expect("registry snapshot parses");
        qrazor::obs::validate_registry_json(&parsed).expect("registry snapshot schema");
    }
    qrazor::obs::set_timing(false);
    println!("spec_decode OK");
}
