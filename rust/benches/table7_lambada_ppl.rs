//! Table 7 (Appendix A.3) — Lambada-style perplexity across group sizes
//! {8,16,32,64,128} × configs {W4A8, W4A4, W4A8KV4, W4A4KV4}.
//!
//! Shape claims: every config's ppl rises with group size; KV4 variants
//! are worse than their FP-KV counterparts; the W4A4KV4 g128 corner is
//! the worst cell (the paper's 19.2 blow-up cell).

use qrazor::baselines::QRazor;
use qrazor::eval::harness::{build_experiment, EvalScale};
use qrazor::eval::perplexity::perplexity;
use qrazor::model::quantized::QuantModel;

fn main() -> anyhow::Result<()> {
    let scale = EvalScale::from_env();
    let preset = std::env::var("BENCH_MODELS").unwrap_or_else(|_| "tiny".into());
    for preset in preset.split(',') {
        let exp = build_experiment(preset.trim(), scale, 1)?;
        let fp = qrazor::model::FpModel { weights: exp.weights.clone() };
        let base = perplexity(&fp, &exp.lambada_seqs);
        println!("\n=== Table 7 — Lambada ppl vs group size ({preset}) ===");
        println!("baseline (FP): {base:.3}");
        println!(
            "{:<10} {:>8} {:>8} {:>8} {:>8} {:>8}",
            "config", "g8", "g16", "g32", "g64", "g128"
        );
        let groups = [8usize, 16, 32, 64, 128];
        let mut grid: Vec<(String, Vec<f64>)> = Vec::new();
        for (name, mk) in [
            ("W4A8", Box::new(QRazor::w4a8) as Box<dyn Fn(usize) -> QRazor>),
            ("W4A4", Box::new(QRazor::w4a4)),
            ("W4A8KV4", Box::new(QRazor::w4a8kv4)),
            ("W4A4KV4", Box::new(QRazor::w4a4kv4)),
        ] {
            let mut row = Vec::new();
            for &g in &groups {
                let qm = QuantModel::build(&exp.weights, Box::new(mk(g)), &exp.cal);
                row.push(perplexity(&qm, &exp.lambada_seqs));
            }
            println!(
                "{:<10} {:>8.3} {:>8.3} {:>8.3} {:>8.3} {:>8.3}",
                name, row[0], row[1], row[2], row[3], row[4]
            );
            grid.push((name.to_string(), row));
        }
        // monotone-in-group-size within each config (8% noise tolerance)
        for (name, row) in &grid {
            for w in row.windows(2) {
                assert!(
                    w[0] <= w[1] * 1.08,
                    "{name}: ppl must rise with group size ({} -> {})",
                    w[0],
                    w[1]
                );
            }
        }
        // worst corner is the most aggressive config at g128
        let worst = grid
            .iter()
            .flat_map(|(_, r)| r.iter().copied())
            .fold(0f64, f64::max);
        let corner = grid.last().unwrap().1[4]; // W4A4KV4 g128
        assert!(
            corner >= worst * 0.9,
            "W4A4KV4 g128 ({corner}) should be (near-)worst (max {worst})"
        );
    }
    Ok(())
}
