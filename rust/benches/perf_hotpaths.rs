//! Performance microbenches for the L3 hot paths — the §Perf
//! (EXPERIMENTS.md) measurement harness.
//!
//! Paths measured:
//!   1. SDR compression throughput (values/s) — the online activation/
//!      KV encode path.
//!   2. Decompression-free integer GEMM (GMAC/s).
//!   3. Nibble pack/unpack (values/s) — KV-pool write/read.
//!   4. Quantized transformer decode step (tokens/s, single sequence).
//!   5. f32 reference matmul (GFLOP/s) for roofline context.

use qrazor::quant::{Granularity, QuantTensor};
use qrazor::sdr::gemm::gemm_razored_int;
use qrazor::sdr::packed::{
    decode_nibbles_into, decode_nibbles_scalar, pack_nibbles, unpack_nibbles, PackedSdrMatrix,
};
use qrazor::sdr::{SdrMatrix, SdrSpec};
use qrazor::tensor::{matmul_bt, Tensor};
use qrazor::util::rng::Rng;
use qrazor::util::stats::bench_loop;

fn main() {
    let mut rng = Rng::new(1);

    // 1. SDR compression throughput
    let rows = 256;
    let cols = 1024;
    let mut x = Tensor::zeros(&[rows, cols]);
    for v in x.data_mut().iter_mut() {
        *v = rng.heavy_tailed(1.0, 0.02, 25.0);
    }
    let q = QuantTensor::quantize(&x, 16, Granularity::PerTensor);
    let spec = SdrSpec::new(16, 4, 16);
    let r = bench_loop(5, 40, || std::hint::black_box(SdrMatrix::compress(spec, &q)));
    let vals_per_s = (rows * cols) as f64 / r.mean_s;
    println!("sdr_compress      {:>12.1} Mvalues/s   ({})", vals_per_s / 1e6, r.human());

    // 2. decompression-free GEMM
    let (m, n, k) = (64, 256, 1024);
    let mut a_f = Tensor::zeros(&[m, k]);
    rng.fill_normal(a_f.data_mut(), 0.0, 1.0);
    let mut w_f = Tensor::zeros(&[n, k]);
    rng.fill_normal(w_f.data_mut(), 0.0, 0.05);
    let a = SdrMatrix::compress(spec, &QuantTensor::quantize(&a_f, 16, Granularity::PerTensor));
    let w = SdrMatrix::compress(
        SdrSpec::new(8, 4, 16),
        &QuantTensor::quantize(&w_f, 8, Granularity::PerChannel),
    );
    let r = bench_loop(3, 20, || std::hint::black_box(gemm_razored_int(&a, &w)));
    let gmacs = (m * n * k) as f64 / r.mean_s / 1e9;
    println!("razored_gemm      {:>12.2} GMAC/s      ({})", gmacs, r.human());

    // 3. nibble pack/unpack
    let mcodes = SdrMatrix::compress(spec, &q);
    let r = bench_loop(5, 60, || std::hint::black_box(pack_nibbles(&mcodes.codes)));
    println!(
        "nibble_pack       {:>12.1} Mvalues/s   ({})",
        mcodes.codes.len() as f64 / r.mean_s / 1e6,
        r.human()
    );
    let packed = PackedSdrMatrix::from_matrix(&mcodes);
    let r = bench_loop(5, 60, || {
        std::hint::black_box(unpack_nibbles(&packed.nibbles, rows * cols))
    });
    println!(
        "nibble_unpack     {:>12.1} Mvalues/s   ({})",
        (rows * cols) as f64 / r.mean_s / 1e6,
        r.human()
    );

    // 3b. GEMM-path nibble decode: the u64 swizzle (16 codes per load
    // through the 256-entry pair LUT) vs the per-byte walk it replaced
    // — the packed kernels' inner decode, reported as a delta.
    let n_codes = rows * cols;
    let mut decoded = vec![0i16; n_codes];
    let r_swz = bench_loop(5, 60, || {
        decode_nibbles_into(&packed.nibbles, 0, n_codes, &mut decoded);
        std::hint::black_box(decoded[n_codes - 1])
    });
    let swz = n_codes as f64 / r_swz.mean_s / 1e6;
    println!("nibble_decode_u64 {:>12.1} Mvalues/s   ({})", swz, r_swz.human());
    let r_byte = bench_loop(5, 60, || {
        decode_nibbles_scalar(&packed.nibbles, 0, n_codes, &mut decoded);
        std::hint::black_box(decoded[n_codes - 1])
    });
    let byte = n_codes as f64 / r_byte.mean_s / 1e6;
    println!(
        "nibble_decode_byt {:>12.1} Mvalues/s   ({})  — u64 swizzle delta {:.2}x",
        byte,
        r_byte.human(),
        swz / byte
    );

    // 4. quantized decode step (tiny model)
    let cfg = qrazor::config::ModelConfig::preset("tiny").unwrap();
    let wts = qrazor::model::ModelWeights::init_random(&cfg, 3);
    let calib: Vec<Vec<u32>> = (0..2)
        .map(|_| (0..32).map(|_| rng.below(cfg.vocab as u64) as u32).collect())
        .collect();
    let cal = qrazor::model::quantized::calibrate(&wts, &calib);
    let qm = qrazor::model::quantized::QuantModel::build(
        &wts,
        Box::new(qrazor::baselines::QRazor::w4a4kv4(16)),
        &cal,
    );
    let mut cache = qm.new_cache(16);
    // warm the cache to a realistic 64-token context
    for pos in 0..64 {
        qm.forward_token((pos % cfg.vocab) as u32, pos, &mut cache);
    }
    let mut pos = 64;
    let r = bench_loop(2, 20, || {
        let l = qm.forward_token(7, pos, &mut cache);
        pos += 1;
        std::hint::black_box(l)
    });
    println!(
        "decode_step(tiny) {:>12.1} tok/s       ({})",
        1.0 / r.mean_s,
        r.human()
    );

    // 5. f32 roofline context
    let r = bench_loop(3, 20, || std::hint::black_box(matmul_bt(&a_f, &w_f)));
    println!(
        "f32_matmul        {:>12.2} GFLOP/s     ({})",
        2.0 * (m * n * k) as f64 / r.mean_s / 1e9,
        r.human()
    );
}
