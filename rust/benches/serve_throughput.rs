//! Serving throughput/latency of the L3 coordinator — the deployment
//! claim behind the paper's efficiency story: QRazor's 4-bit KV pool
//! lets the same memory budget hold more concurrent sequences, and the
//! decompression-free arithmetic keeps per-token cost flat.
//!
//! Measures tokens/s and TTFT across batch sizes for FP-KV vs SDR-KV,
//! the batching-policy ablation (FCFS vs shortest-prefill-first), the
//! sharded scale-out sweep, and a streaming-latency axis: per-request
//! TTFT and inter-token p50/p95 measured from `TokenEvent` timestamps
//! across shard counts and priority mixes, through the same `ServeApi`
//! the CLI and example use. `--health` runs the numeric-health axis:
//! a stale-calibration distribution shift that must trip the drift
//! alarms and the escalation advisor. `--smoke` runs the reduced CI
//! sweep.

use std::collections::BTreeMap;
use std::time::Instant;

use qrazor::baselines::{Fp16, QRazor};
use qrazor::cluster::{ClusterConfig, ClusterServer};
use qrazor::config::{ModelConfig, ServeConfig};
use qrazor::coordinator::batcher::Policy;
use qrazor::coordinator::request::Sampling;
use qrazor::coordinator::{
    collect_sessions, Engine, Priority, RequestId, ServeApi, Server, SubmitOptions,
};
use qrazor::model::quantized::{calibrate, QuantModel};
use qrazor::model::ModelWeights;
use qrazor::util::rng::Rng;
use qrazor::util::stats::Percentiles;

fn build(scheme: Box<dyn qrazor::baselines::Scheme>) -> QuantModel {
    let cfg = ModelConfig::preset("nano").unwrap();
    let w = ModelWeights::init_random(&cfg, 3);
    let mut rng = Rng::new(4);
    let seqs: Vec<Vec<u32>> = (0..2)
        .map(|_| (0..32).map(|_| rng.below(cfg.vocab as u64) as u32).collect())
        .collect();
    let cal = calibrate(&w, &seqs);
    QuantModel::build(&w, scheme, &cal)
}

fn run(engine: &mut Engine, n_requests: usize, max_new: usize, seed: u64) -> (f64, usize) {
    let vocab = engine.model.config.vocab as u64;
    let mut rng = Rng::new(seed);
    for _ in 0..n_requests {
        let len = 4 + rng.index(16);
        let prompt: Vec<u32> = (0..len).map(|_| rng.below(vocab) as u32).collect();
        engine.submit(prompt, max_new, Sampling::Greedy);
    }
    let t0 = std::time::Instant::now();
    let done = engine.run_to_completion();
    assert_eq!(done.len(), n_requests);
    let dt = t0.elapsed().as_secs_f64();
    (
        engine.metrics.generated_tokens as f64 / dt,
        engine.metrics.kv_bytes_peak,
    )
}

/// Per-request latency percentiles from a streamed workload: TTFT is
/// submit→first `Token` event, inter-token gaps are per committed
/// token between consecutive `Token` events. Generic over [`ServeApi`]
/// — the same driver measures one engine or N shards.
fn streaming_latency(
    api: &impl ServeApi,
    n_requests: usize,
    max_new: usize,
    vocab: u64,
    mix: &[Priority],
    seed: u64,
) -> (Percentiles, Percentiles) {
    let mut rng = Rng::new(seed);
    let mut submit_at: BTreeMap<RequestId, Instant> = BTreeMap::new();
    for i in 0..n_requests {
        let len = 4 + rng.index(12);
        let prompt: Vec<u32> = (0..len).map(|_| rng.below(vocab) as u32).collect();
        let opts = SubmitOptions::new().priority(mix[i % mix.len()]);
        let id = api.submit_with(prompt, max_new, opts).expect("submit");
        submit_at.insert(id, Instant::now());
    }
    let sessions = collect_sessions(api, n_requests).expect("stream");
    let mut ttft = Percentiles::default();
    let mut gaps = Percentiles::default();
    for (id, at) in &submit_at {
        let log = &sessions[id];
        let resp = log.response.as_ref().expect("finished");
        assert_eq!(resp.tokens.len(), max_new, "every stream runs to its budget");
        assert_eq!(log.tokens(), resp.tokens, "streamed ≡ batch");
        ttft.push(log.ttft_s(*at).expect("first token streamed"));
        for g in log.inter_token_gaps_s() {
            gaps.push(g);
        }
    }
    (ttft, gaps)
}

/// Shared-prefix workload axis: sessions share a prompt preamble of
/// `prefix_len` tokens against a fixed page pool, versus a control
/// where every session carries its own same-length preamble. Copy-on-
/// write prefix pages are charged once, so the shared workload's
/// admitted concurrency should rise superlinearly with the shared
/// fraction while the control stays pinned at the unshared bound —
/// the paged-KV capacity claim, measured end to end through the
/// engine's own admission path.
fn shared_prefix_axis(smoke: bool) {
    let pool_tokens = 256usize; // 16 pages of 16 tokens
    let page_tokens = 16usize;
    let suffix_len = 12usize;
    let max_new = 8usize;
    let n_requests = if smoke { 16usize } else { 24 };
    let prefix_axis: &[usize] = if smoke { &[0, 96] } else { &[0, 16, 48, 96] };
    println!(
        "\n=== shared-prefix axis ({n_requests} sessions, {pool_tokens}-token pool, \
         {suffix_len}-token suffixes, {max_new} new) ==="
    );
    println!(
        "{:<12} {:>9} {:>12} {:>12} {:>10} {:>12} {:>13}",
        "prefix len", "shared %", "peak shared", "peak unique", "hit rate", "reused tok",
        "oversubscribe"
    );
    // peak concurrent sessions for one workload shape; `shared` picks
    // one preamble for all sessions vs one preamble each
    let mut run_axis = |prefix_len: usize, shared: bool| -> (usize, u64, u64) {
        let qm = build(Box::new(QRazor::w4a4kv4(16)));
        let vocab = qm.config.vocab as u64;
        let mut engine = Engine::new(
            qm,
            ServeConfig {
                max_batch: n_requests,
                max_new_tokens: max_new,
                kv_pool_tokens: pool_tokens,
                kv_page_tokens: page_tokens,
                ..Default::default()
            },
        );
        let mut rng = Rng::new(17);
        let preamble: Vec<u32> = (0..prefix_len).map(|_| rng.below(vocab) as u32).collect();
        for _ in 0..n_requests {
            let mut prompt = if shared {
                preamble.clone()
            } else {
                (0..prefix_len).map(|_| rng.below(vocab) as u32).collect()
            };
            prompt.extend((0..suffix_len).map(|_| rng.below(vocab) as u32));
            engine.submit(prompt, max_new, Sampling::Greedy);
        }
        let mut peak = 0usize;
        while !engine.is_idle() {
            engine.step();
            peak = peak.max(engine.pool_occupancy().live_sequences);
        }
        assert_eq!(engine.take_completed().len(), n_requests);
        assert_eq!(engine.kv_bytes(), 0, "pool drained");
        (peak, engine.metrics.prefix_hits, engine.metrics.reused_tokens)
    };
    let capacity_pages = pool_tokens / page_tokens;
    let mut half_shared: Option<(usize, usize)> = None;
    for &prefix_len in prefix_axis {
        let (peak_shared, hits, reused) = run_axis(prefix_len, true);
        let (peak_unique, _, _) = run_axis(prefix_len, false);
        let need = prefix_len + suffix_len + max_new - 1;
        let pages_per = need.div_ceil(page_tokens);
        let shared_frac = prefix_len as f64 / need as f64;
        // virtual pages the peak concurrent sessions would cost unshared,
        // over the physical pool: >1 is capacity the prefix index created
        let oversub = (peak_shared * pages_per) as f64 / capacity_pages as f64;
        println!(
            "{:<12} {:>8.0}% {:>12} {:>12} {:>10.2} {:>12} {:>12.2}x",
            prefix_len,
            100.0 * shared_frac,
            peak_shared,
            peak_unique,
            hits as f64 / n_requests as f64,
            reused,
            oversub,
        );
        if shared_frac >= 0.5 && half_shared.is_none() {
            half_shared = Some((peak_shared, peak_unique));
            // every session after the first prefills through the index
            assert_eq!(hits, n_requests as u64 - 1, "all but the cold session hit");
            assert!(
                oversub > 1.5,
                "≥50% shared prefix must oversubscribe the pool, got {oversub:.2}x"
            );
        }
    }
    let (peak_shared, peak_unique) = half_shared.expect("axis covers a ≥50% shared point");
    assert!(
        peak_shared >= 2 * peak_unique,
        "shared-prefix capacity must be superlinear vs the unshared control: \
         {peak_shared} vs {peak_unique} concurrent sessions"
    );
}

/// Telemetry axis: a mixed workload (priority classes, speculative
/// draft/verify pair, shared prompt prefixes) on a 2-shard cluster
/// with stage timing and tracing on. Prints the per-stage latency
/// breakdown per shard and merged, plus the hot-path aggregates, then
/// writes the merged registry snapshot (`BENCH_serve_throughput.json`
/// or `--metrics-json PATH`) and optionally a Chrome trace
/// (`--trace-out PATH`). `--smoke` re-parses and schema-checks every
/// artifact it wrote.
fn telemetry_axis(smoke: bool, metrics_path: &str, trace_path: &str) {
    use qrazor::obs;
    obs::set_timing(true);
    obs::hot_reset();
    let n_requests = if smoke { 10usize } else { 24 };
    let max_new = 10usize;
    println!(
        "\n=== telemetry axis ({n_requests} requests × {max_new} tokens, 2 shards, \
         spec k=2, priority mix, shared prefixes) ==="
    );
    // Same weights + calibration both times (build() is deterministic),
    // so the draft is the razored form of the target.
    let target = build(Box::new(QRazor::w4a8kv4(16)));
    let draft = std::sync::Arc::new(build(Box::new(QRazor::w4a4kv4(16))));
    let vocab = target.config.vocab as u64;
    let trace = qrazor::obs::TraceBuffer::with_default_capacity();
    let cluster = ClusterServer::spawn_with_telemetry(
        target,
        Some(draft),
        ClusterConfig {
            shards: 2,
            serve: ServeConfig {
                max_batch: 4,
                max_new_tokens: max_new,
                spec_k: 2,
                ..Default::default()
            },
            ..Default::default()
        },
        Some(trace.clone()),
    );
    let mut rng = Rng::new(29);
    let preamble: Vec<u32> = (0..12).map(|_| rng.below(vocab) as u32).collect();
    let mix = [Priority::Interactive, Priority::Standard, Priority::Batch];
    for i in 0..n_requests {
        let mut prompt = if i % 2 == 0 { preamble.clone() } else { Vec::new() };
        let len = 4 + rng.index(8);
        prompt.extend((0..len).map(|_| rng.below(vocab) as u32));
        cluster
            .submit_with(prompt, max_new, SubmitOptions::new().priority(mix[i % mix.len()]))
            .expect("submit");
    }
    let sessions = collect_sessions(&cluster, n_requests).expect("stream");
    assert_eq!(sessions.len(), n_requests);
    let report = cluster.shutdown();
    for s in &report.shards {
        print!(
            "{}",
            s.metrics.stages.render_table(&format!("stage latency, shard {} (ms)", s.index))
        );
    }
    let merged = report.merged_metrics();
    print!("{}", merged.stages.render_table("stage latency, merged (ms)"));
    for (name, ns, calls) in obs::hot_snapshot() {
        if calls > 0 {
            println!("  hot {name:<18} {calls:>10} calls {:>12.3} ms total", ns as f64 * 1e-6);
        }
    }
    let mut reg = report.registry();
    obs::export_hot(&mut reg);
    let json = reg.to_json().to_string();
    std::fs::write(metrics_path, &json).expect("write registry snapshot");
    println!("registry snapshot -> {metrics_path}");
    if !trace_path.is_empty() {
        std::fs::write(trace_path, trace.to_chrome_json().to_string()).expect("write trace");
        println!("chrome trace ({} events) -> {trace_path}", trace.events().len());
    }
    if smoke {
        let parsed = qrazor::util::json::Json::parse(&json).expect("registry snapshot parses");
        obs::validate_registry_json(&parsed).expect("registry snapshot schema");
        let bad = obs::unbalanced_spans(&trace.events());
        assert!(bad.is_empty(), "unbalanced trace spans: {bad:?}");
        assert!(merged.stages.get(obs::Stage::Decode).is_some(), "decode stage timed");
        assert!(merged.stages.get(obs::Stage::Publish).is_some(), "publish stage timed");
    }
    obs::set_timing(false);
}

/// Numeric-health axis: the same nano serve run twice through the
/// drift probes — once with fresh calibration (no alarms), once with
/// the frozen scales attenuated to 0.4× so the live activations sit
/// ~2.5× past the calibrated range (the stale-calibration /
/// distribution-shift failure mode). The second run must trip the
/// per-site drift alarms and the escalation advisor, whose suggested
/// policy must measurably reduce the activation razoring error.
/// Writes the `BENCH_quant_health.json` summary (drift p50/p99, alarm
/// counts, pre/post-escalation error, embedded health snapshot);
/// `--smoke` schema-checks it.
fn health_axis(smoke: bool) {
    use qrazor::obs;
    use qrazor::policy::health::HealthReport;
    use qrazor::policy::QuantPolicy;
    use qrazor::util::json::Json;

    let n_requests = if smoke { 8usize } else { 16 };
    let max_new = 12usize;
    println!(
        "\n=== numeric-health axis ({n_requests} requests × {max_new} tokens, \
         probe every 2 steps) ==="
    );
    let cfg = ModelConfig::preset("nano").unwrap();
    let w = ModelWeights::init_random(&cfg, 3);
    // Generous calibration (most of the vocab) so the healthy phase's
    // live amax stays inside the frozen range at every site.
    let mut rng = Rng::new(4);
    let seqs: Vec<Vec<u32>> = (0..32)
        .map(|_| (0..32).map(|_| rng.below(cfg.vocab as u64) as u32).collect())
        .collect();
    let policy = QuantPolicy::parse("w4a4kv4:16").unwrap();
    let serve = ServeConfig {
        max_batch: 4,
        max_new_tokens: max_new,
        health: obs::HealthConfig { sample_every_n_steps: 2, ..Default::default() },
        ..Default::default()
    };
    obs::set_health(true);
    // One phase = build from (possibly attenuated) calibration, serve
    // the deterministic workload on a plain engine, return its health.
    let run_phase = |attenuation: Option<f32>| -> obs::HealthStats {
        obs::health_reset();
        let mut cal = calibrate(&w, &seqs);
        if let Some(f) = attenuation {
            cal.calibrator.attenuate(f);
        }
        let qm = QuantModel::build(&w, policy.clone(), &cal);
        let vocab = qm.config.vocab as u64;
        let mut engine = Engine::new(qm, serve.clone());
        let mut rng = Rng::new(7);
        for _ in 0..n_requests {
            let len = 4 + rng.index(16);
            let prompt: Vec<u32> = (0..len).map(|_| rng.below(vocab) as u32).collect();
            engine.submit(prompt, max_new, Sampling::Greedy);
        }
        let done = engine.run_to_completion();
        assert_eq!(done.len(), n_requests);
        std::mem::take(&mut engine.metrics.health)
    };
    let healthy = run_phase(None);
    let shifted = run_phase(Some(0.4));
    let snapshot = obs::health_json(Some(&shifted));
    obs::set_health(false);
    println!(
        "  healthy: {} probe steps, {} alarms, drift p50 {:.2}",
        healthy.probe_steps,
        healthy.drift_alarms,
        healthy.drift.pct(50.0)
    );
    println!(
        "  shifted: {} probe steps, {} alarms, drift p50 {:.2} p99 {:.2}",
        shifted.probe_steps,
        shifted.drift_alarms,
        shifted.drift.pct(50.0),
        shifted.drift.pct(99.0)
    );
    let rep = HealthReport::from_stats(&shifted, &policy, 8);
    print!("{}", rep.render_table());
    let advice = rep.advice.as_ref().expect("shift workload must trip the advisor");
    let cal = calibrate(&w, &seqs);
    let err_before = policy.act_calibration_error(&cal, cfg.layers);
    let err_after = advice.escalated.act_calibration_error(&cal, cfg.layers);
    println!("  advisor escalation: razoring error {err_before:.4} -> {err_after:.4}");
    let summary = Json::from_pairs(vec![
        ("healthy_alarms", Json::from(healthy.drift_alarms as f64)),
        ("shifted_alarms", Json::from(shifted.drift_alarms as f64)),
        ("drift_p50", Json::from(shifted.drift.pct(50.0))),
        ("drift_p99", Json::from(shifted.drift.pct(99.0))),
        ("err_before", Json::from(err_before)),
        ("err_after", Json::from(err_after)),
        ("advice_dsl", Json::from(advice.dsl.as_str())),
        ("health", snapshot),
    ]);
    std::fs::write("BENCH_quant_health.json", summary.to_string()).expect("write health bench");
    println!("health summary -> BENCH_quant_health.json");
    // The axis's contract — cheap enough to pin on every run.
    assert_eq!(
        healthy.drift_alarms, 0,
        "healthy phase must not alarm (drift p50 {:.2})",
        healthy.drift.pct(50.0)
    );
    assert!(
        shifted.drift_alarms >= 5,
        "stale scales must trip per-site alarms, got {}",
        shifted.drift_alarms
    );
    assert!(
        shifted.drift.pct(50.0) > 1.6,
        "shifted drift p50 should sit near 2.5x, got {:.2}",
        shifted.drift.pct(50.0)
    );
    assert!(
        err_after < err_before,
        "advisor escalation must reduce razoring error: {err_before:.4} -> {err_after:.4}"
    );
    if smoke {
        let parsed = Json::parse(&summary.to_string()).expect("health summary parses");
        obs::validate_health_json(parsed.req("health").expect("embedded snapshot"))
            .expect("health snapshot schema");
    }
}

fn main() {
    let smoke = std::env::args().any(|a| a == "--smoke");
    if std::env::args().any(|a| a == "--shared-prefix") {
        // CI entry: just the paged-KV capacity axis
        shared_prefix_axis(smoke);
        println!("serve_throughput OK");
        return;
    }
    let argv: Vec<String> = std::env::args().collect();
    let arg_val = |name: &str| -> Option<String> {
        argv.iter().position(|a| a == name).and_then(|i| argv.get(i + 1).cloned())
    };
    let metrics_path =
        arg_val("--metrics-json").unwrap_or_else(|| "BENCH_serve_throughput.json".to_string());
    let trace_path = arg_val("--trace-out").unwrap_or_default();
    if std::env::args().any(|a| a == "--telemetry") {
        // CI entry: just the telemetry axis
        telemetry_axis(smoke, &metrics_path, &trace_path);
        println!("serve_throughput OK");
        return;
    }
    if std::env::args().any(|a| a == "--health") {
        // CI entry: just the numeric-health / drift-advisor axis
        health_axis(smoke);
        println!("serve_throughput OK");
        return;
    }
    println!("\n=== serving throughput (nano model, 16 requests × 16 new tokens) ===");
    println!("{:<22} {:>8} {:>12} {:>14}", "config", "batch", "tok/s", "kv peak bytes");
    for batch in [1usize, 4, 8] {
        for (name, scheme) in [
            ("FP-KV (Fp16)", Box::new(Fp16) as Box<dyn qrazor::baselines::Scheme>),
            ("SDR-KV (W4A4KV4 g16)", Box::new(QRazor::w4a4kv4(16))),
        ] {
            let qm = build(scheme);
            let mut engine = Engine::new(
                qm,
                ServeConfig { max_batch: batch, max_new_tokens: 16, ..Default::default() },
            );
            let (tps, kv_peak) = run(&mut engine, 16, 16, 7);
            println!("{:<22} {:>8} {:>12.1} {:>14}", name, batch, tps, kv_peak);
        }
    }

    // --- packed-native vs staged compute: operand bytes moved + tok/s ---
    // The tentpole claim: with packed weights and packed KV attention the
    // serving path streams ≈½ the operand bytes (4.25 vs 8.5 eff. bits)
    // at no throughput cost.
    println!("\n=== packed-native vs staged compute (W4A4KV4 g16, batch 4) ===");
    // `streamed` comes from the kernels' own traffic counter, so a
    // silent fallback to the staged branch (use_packed threading bug,
    // missing PackedWeight, unsupported head geometry) shows up as
    // zero packed bytes rather than a falsely green ratio.
    let measure = |use_packed: bool| {
        let mut qm = build(Box::new(QRazor::w4a4kv4(16)));
        qm.use_packed = use_packed;
        let (wp, wu) = qm.weight_operand_bytes();
        let mut engine = Engine::new(
            qm,
            ServeConfig { max_batch: 4, max_new_tokens: 16, ..Default::default() },
        );
        let before = qrazor::sdr::gemm::packed_operand_bytes();
        let (tps, _) = run(&mut engine, 16, 16, 7);
        let streamed = qrazor::sdr::gemm::packed_operand_bytes() - before;
        let kv_packed = engine.metrics.kv_bytes_peak;
        let kv_unpacked = engine.metrics.kv_bytes_unpacked_peak;
        (tps, wp, wu, kv_packed, kv_unpacked, streamed)
    };
    let (tps_packed, wp, wu, kvp, kvu, streamed_packed) = measure(true);
    let (tps_staged, _, _, _, _, streamed_staged) = measure(false);
    let weight_ratio = wp as f64 / wu as f64;
    let kv_ratio = kvp as f64 / kvu as f64;
    let wr_pct = 100.0 * weight_ratio;
    let kv_pct = 100.0 * kv_ratio;
    println!("  weights : packed {wp} B vs unpacked {wu} B per forward ({wr_pct:.1}%)");
    println!("  kv peak : packed {kvp} B vs unpacked-equiv {kvu} B ({kv_pct:.1}%)");
    println!(
        "  streamed: packed kernels consumed {streamed_packed} B \
         (staged run: {streamed_staged} B)"
    );
    println!("  tok/s   : packed {tps_packed:.1} vs staged {tps_staged:.1}");
    assert!(
        streamed_packed > 0 && streamed_staged == 0,
        "packed run must exercise the packed kernels and the staged run must not \
         ({streamed_packed} vs {streamed_staged} bytes)"
    );
    assert!(
        weight_ratio <= 0.55,
        "packed weights must move ≤55% of unpacked operand bytes, got {weight_ratio:.3}"
    );
    assert!(
        kv_ratio <= 0.55,
        "packed KV must hold ≤55% of unpacked-equivalent bytes, got {kv_ratio:.3}"
    );
    // Throughput parity: "no regression", with a bounded noise margin —
    // the nano model's decode quantum is microseconds, so exact >= 1.0
    // would flake on scheduler jitter.
    assert!(
        tps_packed >= tps_staged * 0.8,
        "packed path regressed tokens/s: {tps_packed:.1} vs {tps_staged:.1}"
    );

    println!("\n=== batching-policy ablation (mixed prompt lengths) ===");
    for policy in [Policy::Fcfs, Policy::ShortestPrefillFirst] {
        let qm = build(Box::new(QRazor::w4a4kv4(16)));
        let mut engine = Engine::new(
            qm,
            ServeConfig { max_batch: 4, max_new_tokens: 12, ..Default::default() },
        );
        engine.set_policy(policy);
        // one long prompt then many short ones — the HoL-blocking shape
        let vocab = engine.model.config.vocab as u64;
        let mut rng = Rng::new(11);
        let mut mk =
            |len: usize| -> Vec<u32> { (0..len).map(|_| rng.below(vocab) as u32).collect() };
        engine.submit(mk(96), 12, Sampling::Greedy);
        for _ in 0..8 {
            engine.submit(mk(6), 12, Sampling::Greedy);
        }
        let t0 = std::time::Instant::now();
        let _ = engine.run_to_completion();
        println!(
            "{:?}: ttft p50 {:.1} ms, total {:.2}s, {}",
            policy,
            engine.metrics.ttft.pct(50.0) * 1e3,
            t0.elapsed().as_secs_f64(),
            engine.metrics.render()
        );
    }

    // --- sharded cluster scale-out: aggregate tok/s across --shards N ---
    // Each shard is a full engine with its own packed KV pool; all
    // shards read one Arc-shared copy of the nibble-packed weights.
    // `--shards N` pins a single axis point; default sweeps 1/2/4.
    let shard_axis: Vec<usize> = {
        let args: Vec<String> = std::env::args().collect();
        match args.iter().position(|a| a == "--shards") {
            Some(i) => vec![args
                .get(i + 1)
                .and_then(|v| v.parse().ok())
                .expect("--shards N")],
            None if smoke => vec![1, 2],
            None => vec![1, 2, 4],
        }
    };
    println!("\n=== sharded cluster scale-out (W4A4KV4 g16, 32 requests × 16 new tokens) ===");
    println!(
        "{:<8} {:>14} {:>12} {:>10}  per-shard kv peak bytes",
        "shards", "agg tok/s", "generated", "time s"
    );
    let cluster_requests = if smoke { 12usize } else { 32 };
    // Equal-memory comparison: one fixed KV token budget split across
    // however many shards the axis point runs — the same bytes, spent
    // behind 1 step loop or N.
    let total_kv_tokens = ServeConfig::default().kv_pool_tokens;
    let mut axis_tps: Vec<(usize, f64)> = Vec::new();
    for &shards in &shard_axis {
        let qm = build(Box::new(QRazor::w4a4kv4(16)));
        let vocab = qm.config.vocab as u64;
        let cluster = ClusterServer::spawn(
            qm,
            ClusterConfig {
                shards,
                serve: ServeConfig { max_batch: 4, max_new_tokens: 16, ..Default::default() },
                ..Default::default()
            }
            .split_pool(total_kv_tokens),
        );
        let mut rng = Rng::new(7);
        let t0 = std::time::Instant::now();
        for _ in 0..cluster_requests {
            let len = 4 + rng.index(16);
            let prompt: Vec<u32> = (0..len).map(|_| rng.below(vocab) as u32).collect();
            cluster.submit(prompt, 16, Sampling::Greedy).unwrap();
        }
        let report = cluster.shutdown();
        let dt = t0.elapsed().as_secs_f64();
        assert_eq!(report.total_completed() as usize, cluster_requests);
        let tps = report.total_generated() as f64 / dt;
        let peaks: Vec<String> = report
            .shards
            .iter()
            .map(|s| format!("s{}={}", s.index, s.metrics.kv_bytes_peak))
            .collect();
        println!(
            "{:<8} {:>14.1} {:>12} {:>10.2}  {}",
            shards,
            tps,
            report.total_generated(),
            dt,
            peaks.join(" ")
        );
        // every shard's pool must be byte-exactly drained
        for s in &report.shards {
            assert_eq!(s.final_occupancy.bytes, 0, "shard {} pool not drained", s.index);
        }
        axis_tps.push((shards, tps));
    }
    let cores = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1);
    if let (Some(&(_, t_one)), Some(&(_, t_four))) = (
        axis_tps.iter().find(|(s, _)| *s == 1),
        axis_tps.iter().find(|(s, _)| *s == 4),
    ) {
        println!("shard scaling: 1 -> {t_one:.1} tok/s, 4 -> {t_four:.1} tok/s ({cores} cores)");
        if cores >= 4 {
            assert!(
                t_four > t_one,
                "4 shards must beat 1 shard on {cores} cores: {t_four:.1} vs {t_one:.1} tok/s"
            );
        } else {
            assert!(
                t_four > t_one * 0.7,
                "sharded throughput collapsed on {cores} cores: {t_four:.1} vs {t_one:.1}"
            );
        }
    }

    // --- streaming latency axis: TTFT + inter-token percentiles -------
    // Measured from TokenEvent timestamps through the shared ServeApi,
    // across shard counts and priority mixes — the externally
    // observable latency surface the redesign exists for. One engine
    // and N shards run the exact same driver.
    let stream_requests = if smoke { 8usize } else { 16 };
    let stream_new = 12usize;
    println!(
        "\n=== streaming latency axis ({stream_requests} requests × {stream_new} tokens, \
         TokenEvent timestamps) ==="
    );
    println!(
        "{:<8} {:<22} {:>12} {:>12} {:>14} {:>14}",
        "shards", "priority mix", "ttft p50 ms", "ttft p95 ms", "inter-tok p50", "inter-tok p95"
    );
    let mixes: &[(&str, &[Priority])] = &[
        ("standard only", &[Priority::Standard]),
        (
            "interactive/std/batch",
            &[Priority::Interactive, Priority::Standard, Priority::Batch],
        ),
    ];
    for &shards in &shard_axis {
        for (mix_name, mix) in mixes {
            let qm = build(Box::new(QRazor::w4a4kv4(16)));
            let vocab = qm.config.vocab as u64;
            let serve =
                ServeConfig { max_batch: 4, max_new_tokens: stream_new, ..Default::default() };
            let (ttft, gaps) = if shards > 1 {
                let cluster = ClusterServer::spawn(
                    qm,
                    ClusterConfig { shards, serve, ..Default::default() }
                        .split_pool(total_kv_tokens),
                );
                let r = streaming_latency(&cluster, stream_requests, stream_new, vocab, mix, 21);
                cluster.shutdown();
                r
            } else {
                let server = Server::spawn(qm, serve);
                let r = streaming_latency(&server, stream_requests, stream_new, vocab, mix, 21);
                server.shutdown();
                r
            };
            assert!(ttft.len() == stream_requests, "every request streamed a first token");
            println!(
                "{:<8} {:<22} {:>12.2} {:>12.2} {:>14.3} {:>14.3}",
                shards,
                mix_name,
                ttft.pct(50.0) * 1e3,
                ttft.pct(95.0) * 1e3,
                gaps.pct(50.0) * 1e3,
                gaps.pct(95.0) * 1e3,
            );
        }
    }

    // batch scaling sanity: batched decode must beat batch=1 throughput
    let qm1 = build(Box::new(QRazor::w4a4kv4(16)));
    let mut e1 =
        Engine::new(qm1, ServeConfig { max_batch: 1, max_new_tokens: 16, ..Default::default() });
    let (t1, _) = run(&mut e1, 8, 16, 13);
    let qm8 = build(Box::new(QRazor::w4a4kv4(16)));
    let mut e8 =
        Engine::new(qm8, ServeConfig { max_batch: 8, max_new_tokens: 16, ..Default::default() });
    let (t8, _) = run(&mut e8, 8, 16, 13);
    println!("\nbatch scaling: 1 -> {t1:.1} tok/s, 8 -> {t8:.1} tok/s");
    // On multi-core hosts batching must win (parallel decode); on a
    // single core it must at least not regress (scheduler overhead
    // amortizes across the batch).
    let cores = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1);
    if cores > 1 {
        assert!(t8 > t1, "batching must increase throughput on {cores} cores");
    } else {
        assert!(t8 > t1 * 0.8, "batched throughput regressed: {t8} vs {t1}");
    }

    shared_prefix_axis(smoke);
    telemetry_axis(smoke, &metrics_path, &trace_path);
    health_axis(smoke);
    println!("serve_throughput OK");
}
