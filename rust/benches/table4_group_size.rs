//! Table 4 — SDR group-size ablation at W4A4KV4: g ∈ {8,16,32,64,128},
//! with the effective-bits column, plus the rounding-mode extension
//! ablation (DESIGN.md §10).
//!
//! Shape claims: accuracy degrades monotonically (in ppl) with group
//! size; the g=128 cliff is visible; effective bits match the paper's
//! row exactly (4.5 / 4.25 / 4.125 / 4.06 / 4.03).

use qrazor::baselines::QRazor;
use qrazor::eval::harness::{build_experiment, render_table, EvalScale};
use qrazor::sdr::SdrSpec;

fn main() -> anyhow::Result<()> {
    let scale = EvalScale::from_env();
    let preset = std::env::var("BENCH_MODELS").unwrap_or_else(|_| "tiny".into());
    for preset in preset.split(',') {
        let exp = build_experiment(preset.trim(), scale, 1)?;
        let mut rows = vec![exp.eval_fp()];
        let groups = [8usize, 16, 32, 64, 128];
        println!("\nEffective bits per value (paper row):");
        for &g in &groups {
            let spec = SdrSpec::new(16, 4, g);
            println!("  g{g:<4} -> {:.5} bits", spec.effective_bits());
        }
        for &g in &groups {
            rows.push(exp.eval_scheme(Box::new(QRazor::w4a4kv4(g))));
        }
        println!(
            "{}",
            render_table(&format!("Table 4 — W4A4KV4 group-size ablation ({preset})"), &rows)
        );
        // monotone ppl in group size (weakly, 5% tolerance for noise)
        for w in rows[1..].windows(2) {
            assert!(
                w[0].ppl_wiki <= w[1].ppl_wiki * 1.08,
                "{} ppl {} should not exceed {} ppl {}",
                w[0].name,
                w[0].ppl_wiki,
                w[1].name,
                w[1].ppl_wiki
            );
        }
        // cliff: g128 clearly worse than g8
        assert!(
            rows[5].ppl_wiki > rows[1].ppl_wiki,
            "g128 ({}) must be worse than g8 ({})",
            rows[5].ppl_wiki,
            rows[1].ppl_wiki
        );
    }
    Ok(())
}
