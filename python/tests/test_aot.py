"""AOT pipeline test: lowering produces parseable HLO text and a
manifest whose parameter order matches the model's canonical order.

Guards the L2→L3 interchange contract without needing the Rust side.
"""

import json
import pathlib
import subprocess
import sys

import pytest


@pytest.fixture(scope="module")
def artifacts(tmp_path_factory):
    out = tmp_path_factory.mktemp("aot")
    subprocess.run(
        [sys.executable, "-m", "compile.aot", "--outdir", str(out), "--model", "nano"],
        check=True,
        cwd=pathlib.Path(__file__).resolve().parents[1],
    )
    return out


def test_all_artifacts_emitted(artifacts):
    names = {p.name for p in artifacts.iterdir()}
    for expected in [
        "meta.json",
        "train_step.hlo.txt",
        "lm_logits_fp.hlo.txt",
        "lm_logits_w4a4.hlo.txt",
        "sdr_fakequant.hlo.txt",
    ]:
        assert expected in names, f"missing {expected}: {names}"


def test_hlo_is_text_with_entry(artifacts):
    for f in artifacts.glob("*.hlo.txt"):
        text = f.read_text()
        assert "HloModule" in text, f.name
        assert "ENTRY" in text, f.name
        # jax>=0.5 protos are rejected by xla_extension 0.5.1; text must
        # not be a serialized proto blob
        assert text.isprintable() or "\n" in text


def test_meta_matches_model_order(artifacts):
    from compile import model as M

    meta = json.loads((artifacts / "meta.json").read_text())
    cfg = M.PRESETS[meta["model"]["name"]]
    expect = [(n, list(s)) for n, s in M.param_order(cfg)]
    got = [(p["name"], p["shape"]) for p in meta["params"]]
    assert got == expect


def test_meta_shapes_are_consistent(artifacts):
    meta = json.loads((artifacts / "meta.json").read_text())
    m = meta["model"]
    assert m["dim"] % m["heads"] == 0
    assert meta["train"]["batch"] > 0
    assert meta["eval"]["batch"] == 1
    total = sum(
        int.__mul__(*(p["shape"] + [1])[:2]) if len(p["shape"]) == 2 else p["shape"][0]
        for p in meta["params"]
    )
    assert total > 100_000  # nano is ~115k params
