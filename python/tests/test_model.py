"""L2 model tests: shapes, causality, parity plumbing, training signal."""

import numpy as np
import jax
import jax.numpy as jnp

from compile import model as M
from compile import train as T


CFG = M.PRESETS["nano"]


def _params(seed=0):
    return M.init_params(CFG, jax.random.PRNGKey(seed))


def test_param_order_matches_shapes():
    params = _params()
    for name, shape in M.param_order(CFG):
        assert params[name].shape == shape, name


def test_forward_shape_and_finite():
    params = _params()
    tokens = jnp.asarray([[1, 2, 3, 4, 5, 6]], jnp.int32)
    logits = M.forward(params, tokens, CFG)
    assert logits.shape == (1, 6, CFG.vocab)
    assert bool(jnp.all(jnp.isfinite(logits)))


def test_causality():
    params = _params()
    a = M.forward(params, jnp.asarray([[5, 6, 7, 8]], jnp.int32), CFG)
    b = M.forward(params, jnp.asarray([[5, 6, 7, 9]], jnp.int32), CFG)
    np.testing.assert_allclose(np.asarray(a[0, :3]), np.asarray(b[0, :3]),
                               rtol=1e-5, atol=1e-5)
    assert float(jnp.max(jnp.abs(a[0, 3] - b[0, 3]))) > 1e-4


def test_gqa_forward():
    cfg = M.PRESETS["mistral-tiny"]
    params = M.init_params(cfg, jax.random.PRNGKey(1))
    tokens = jnp.asarray([[1, 2, 3]], jnp.int32)
    logits = M.forward(params, tokens, cfg)
    assert logits.shape == (1, 3, cfg.vocab)


def test_loss_decreases_over_steps():
    params = _params(2)
    order = [n for n, _ in M.param_order(CFG)]
    m = {n: jnp.zeros_like(params[n]) for n in order}
    v = {n: jnp.zeros_like(params[n]) for n in order}
    key = jax.random.PRNGKey(3)
    # simple learnable structure: token t+1 = (t + 1) % 32
    base = jnp.arange(64, dtype=jnp.int32) % 32
    tokens = jnp.stack([base + i for i in range(4)]) % 32

    step_fn = jax.jit(lambda s, tk, *flat: T.train_step_flat(CFG, s, tk, *flat))
    flat = [params[n] for n in order] + [m[n] for n in order] + [v[n] for n in order]
    losses = []
    for s in range(8):
        del key
        out = step_fn(jnp.float32(s), tokens, *flat)
        losses.append(float(out[0]))
        flat = list(out[1:])
        key = None
    assert losses[-1] < losses[0] - 0.1, losses


def test_quantized_forward_noise_ordering():
    params = _params(4)
    tokens = jnp.asarray([[3, 1, 4, 1, 5, 9, 2, 6]], jnp.int32)
    fp = M.forward(params, tokens, CFG)

    def rel(a_target, w_target):
        qc = M.QuantConfig(a_target=a_target, w_target=w_target,
                           use_pallas=False)
        q = M.forward(params, tokens, CFG, qc)
        return float(jnp.linalg.norm(q - fp) / jnp.linalg.norm(fp))

    e_w8a8 = rel(8, 8)
    e_w4a8 = rel(8, 4)
    e_w4a4 = rel(4, 4)
    assert e_w8a8 < e_w4a8 < e_w4a4 * 1.001, (e_w8a8, e_w4a8, e_w4a4)
    assert e_w8a8 < 0.1, e_w8a8
    assert e_w4a4 < 1.0, e_w4a4


def test_rope_matches_expected_rotation():
    hd = 8
    x = jnp.ones((1, 2, hd), jnp.float32)
    out = M.apply_rope(x, 1, hd)
    # position 0 identity
    np.testing.assert_allclose(np.asarray(out[0, 0]), np.ones(hd), rtol=1e-6)
    # norms preserved per pair at position 1
    a, b = np.asarray(out[0, 1, :4]), np.asarray(out[0, 1, 4:])
    np.testing.assert_allclose(a * a + b * b, np.full(4, 2.0), rtol=1e-5)
