"""L1 correctness: Pallas SDR kernels vs the pure-jnp oracle (ref.py),
plus a hand-computed bit-level reference for absolute ground truth.

The dequantized lattices are exact integer multiples of the scale, so
kernel-vs-oracle comparisons use strict equality, not allclose.
"""

import numpy as np
import pytest
import jax
import jax.numpy as jnp
from hypothesis import given, settings, strategies as st

from compile.kernels import ref, sdr


# ---------------------------------------------------------------------------
# ground-truth bit-level SDR in plain python
# ---------------------------------------------------------------------------
def py_sdr_group(vals, sal, max_flag):
    """Reference: one group of base-precision ints -> reconstructed ints."""
    m_or = 0
    for v in vals:
        m_or |= abs(v)
    if m_or == 0:
        flag = 0
    else:
        r = m_or.bit_length() - 1
        flag = min(max(r - (sal - 1), 0), max_flag)
    all_ones = (1 << sal) - 1
    out = []
    for v in vals:
        mag = abs(v)
        code = mag >> flag
        if code != all_ones and flag > 0 and (mag >> (flag - 1)) & 1:
            code += 1
        rec = code << flag
        out.append(-rec if v < 0 else rec)
    return out, flag


def py_sdr(ints, sal, max_flag, group):
    out = []
    for i in range(0, len(ints), group):
        rec, _ = py_sdr_group(ints[i:i + group], sal, max_flag)
        out.extend(rec)
    return out


# ---------------------------------------------------------------------------
# oracle vs ground truth
# ---------------------------------------------------------------------------
@given(
    st.lists(st.integers(min_value=-32767, max_value=32767),
             min_size=16, max_size=64).filter(lambda l: len(l) % 16 == 0),
)
@settings(max_examples=60, deadline=None)
def test_oracle_matches_bit_level_reference(vals):
    q = jnp.asarray(vals, jnp.int32).reshape(1, -1)
    codes, flag, sign = ref.sdr_compress_int(q, 16, 4, 16)
    flag_b = jnp.repeat(flag[..., None], 16, axis=-1).reshape(q.shape)
    recon = np.asarray(sign * jax.lax.shift_left(codes, flag_b)).flatten()
    expect = py_sdr(vals, sal=3, max_flag=12, group=16)
    np.testing.assert_array_equal(recon, np.asarray(expect))


def test_all_ones_floor_guard():
    # 0b11111100 = 252: salient 111 -> floor, never carry into sign
    q = jnp.asarray([[252] + [0] * 15], jnp.int32)
    codes, flag, _ = ref.sdr_compress_int(q, 16, 4, 16)
    assert int(flag[0, 0]) == 5
    assert int(codes[0, 0]) == 0b111


def test_round_up_case():
    # 182 = 0b10110110: salient 101, round bit 1 -> 110
    q = jnp.asarray([[182] + [0] * 15], jnp.int32)
    codes, flag, _ = ref.sdr_compress_int(q, 16, 4, 16)
    assert int(flag[0, 0]) == 5
    assert int(codes[0, 0]) == 0b110


# ---------------------------------------------------------------------------
# pallas kernel vs oracle — exact equality
# ---------------------------------------------------------------------------
@given(
    rows=st.sampled_from([1, 2, 4, 8]),
    cols_g=st.sampled_from([(32, 16), (64, 16), (64, 32), (128, 32)]),
    target=st.sampled_from([4, 8]),
    seed=st.integers(min_value=0, max_value=2**31 - 1),
)
@settings(max_examples=25, deadline=None)
def test_pallas_fakequant_equals_oracle(rows, cols_g, target, seed):
    cols, group = cols_g
    key = jax.random.PRNGKey(seed)
    x = jax.random.normal(key, (rows, cols), jnp.float32) * 3.0
    scale = ref.absmax_scale(x, 16).reshape(1, 1)
    got = sdr.sdr_fake_quant_pallas(
        x, scale, base_bits=16, target_bits=target, group=group, block_rows=rows
    )
    want = ref.sdr_fake_quant(x, scale[0, 0], 16, target, group)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_pallas_fakequant_tiles_rows():
    # multi-tile grid must agree with single-tile
    key = jax.random.PRNGKey(7)
    x = jax.random.normal(key, (64, 64), jnp.float32)
    scale = ref.absmax_scale(x, 16).reshape(1, 1)
    a = sdr.sdr_fake_quant_pallas(x, scale, base_bits=16, target_bits=4,
                                  group=16, block_rows=16)
    b = ref.sdr_fake_quant(x, scale[0, 0], 16, 4, 16)
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


@given(seed=st.integers(min_value=0, max_value=2**31 - 1))
@settings(max_examples=10, deadline=None)
def test_pallas_linear_equals_ref(seed):
    key = jax.random.PRNGKey(seed)
    k1, k2 = jax.random.split(key)
    x = jax.random.normal(k1, (8, 64), jnp.float32)
    w = jax.random.normal(k2, (16, 64), jnp.float32) * 0.1
    scale = ref.absmax_scale(x, 16).reshape(1, 1)
    got = sdr.qrazor_linear_pallas(x, w, scale, w_group=16, a_group=16,
                                   block_m=8, block_n=16)
    want = ref.qrazor_linear_ref(x, w, scale[0, 0], 16, 16)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-5)


def test_outlier_dominates_group():
    # one big value forces small ones to zero (the Fig. 2(c) mechanism)
    x = jnp.asarray([[1000.0] + [0.5] * 15], jnp.float32)
    scale = ref.absmax_scale(x, 16)
    out = np.asarray(ref.sdr_fake_quant(x, scale, 16, 4, 16))
    assert out[0, 0] != 0.0
    assert np.all(out[0, 1:] == 0.0)


def test_base_precision_passthrough():
    # target == base -> plain stage-1 quantization (Table 1 scenarios)
    x = jax.random.normal(jax.random.PRNGKey(3), (4, 32), jnp.float32)
    scale = ref.absmax_scale(x, 16)
    out = ref.sdr_fake_quant(x, scale, 16, 16, 16)
    err = np.max(np.abs(np.asarray(out) - np.asarray(x)))
    assert err <= float(scale) * 0.5 + 1e-7


def test_group_size_monotonicity():
    # larger groups -> (weakly) worse reconstruction on heavy-tailed data
    key = jax.random.PRNGKey(11)
    x = jax.random.normal(key, (16, 128), jnp.float32)
    x = x * (1.0 + 20.0 * (jax.random.uniform(key, x.shape) > 0.99))
    scale = ref.absmax_scale(x, 16)
    errs = []
    for g in [8, 32, 128]:
        out = ref.sdr_fake_quant(x, scale, 16, 4, g)
        errs.append(float(jnp.mean((out - x) ** 2)))
    assert errs[0] <= errs[1] * 1.05 <= errs[2] * 1.1 * 1.05


def test_w4a8_more_accurate_than_w4a4():
    key = jax.random.PRNGKey(13)
    x = jax.random.normal(key, (8, 64), jnp.float32)
    scale = ref.absmax_scale(x, 16)
    e4 = float(jnp.mean((ref.sdr_fake_quant(x, scale, 16, 4, 16) - x) ** 2))
    e8 = float(jnp.mean((ref.sdr_fake_quant(x, scale, 16, 8, 16) - x) ** 2))
    assert e8 < e4


def test_zero_input_is_fixed_point():
    x = jnp.zeros((4, 32), jnp.float32)
    scale = ref.absmax_scale(x, 16)
    out = ref.sdr_fake_quant(x, scale, 16, 4, 16)
    np.testing.assert_array_equal(np.asarray(out), np.zeros((4, 32)))
