"""AOT lowering: JAX → HLO **text** artifacts for the Rust runtime.

Why text: jax ≥ 0.5 serializes HloModuleProto with 64-bit instruction
ids, which xla_extension 0.5.1 (the version the `xla` crate binds)
rejects; the text parser reassigns ids and round-trips cleanly. See
/opt/xla-example/README.md.

Artifacts (under --outdir, default ../artifacts):
  meta.json               config, shapes, parameter order
  train_step.hlo.txt      (step, tokens[B,S], params‖m‖v…) → (loss, …)
  lm_logits_fp.hlo.txt    (tokens[1,S], params…) → logits
  lm_logits_w4a4.hlo.txt  same, every GEMM through the L1 Pallas kernels
  sdr_fakequant.hlo.txt   the standalone SDR kernel (parity tests)

`make artifacts` re-runs this only when compile/*.py changes.
"""

import argparse
import json
import pathlib

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model as M
from . import train as T
from .kernels import sdr as ksdr


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--outdir", default="../artifacts")
    ap.add_argument("--model", default="nano", choices=sorted(M.PRESETS))
    ap.add_argument("--train-batch", type=int, default=8)
    ap.add_argument("--train-seq", type=int, default=64)
    ap.add_argument("--eval-seq", type=int, default=128)
    # legacy single-file interface used by older Makefiles
    ap.add_argument("--out", default=None)
    args = ap.parse_args()

    cfg = M.PRESETS[args.model]
    outdir = pathlib.Path(args.outdir)
    outdir.mkdir(parents=True, exist_ok=True)

    order = M.param_order(cfg)
    param_specs = [jax.ShapeDtypeStruct(s, jnp.float32) for _, s in order]

    artifacts = {}

    def emit(name, lowered):
        text = to_hlo_text(lowered)
        path = outdir / f"{name}.hlo.txt"
        path.write_text(text)
        artifacts[name] = f"{name}.hlo.txt"
        print(f"wrote {path} ({len(text)} chars)")

    # --- train step -------------------------------------------------------
    tokens_train = jax.ShapeDtypeStruct((args.train_batch, args.train_seq), jnp.int32)
    step_spec = jax.ShapeDtypeStruct((), jnp.float32)

    def train_step(step, tokens, *flat):
        return T.train_step_flat(cfg, step, tokens, *flat)

    emit(
        "train_step",
        jax.jit(train_step).lower(step_spec, tokens_train, *(param_specs * 3)),
    )

    # --- fp logits --------------------------------------------------------
    tokens_eval = jax.ShapeDtypeStruct((1, args.eval_seq), jnp.int32)

    def logits_fp(tokens, *flat):
        params = dict(zip([n for n, _ in order], flat))
        return (M.forward(params, tokens, cfg),)

    emit("lm_logits_fp", jax.jit(logits_fp).lower(tokens_eval, *param_specs))

    # --- quantized logits (L1 Pallas kernels inside) -----------------------
    qc = M.QuantConfig()

    def logits_w4a4(tokens, *flat):
        params = dict(zip([n for n, _ in order], flat))
        return (M.forward(params, tokens, cfg, qc),)

    emit("lm_logits_w4a4", jax.jit(logits_w4a4).lower(tokens_eval, *param_specs))

    # --- standalone SDR kernel ---------------------------------------------
    def fakequant(x, scale):
        return (
            ksdr.sdr_fake_quant_pallas(
                x, scale, base_bits=16, target_bits=4, group=16
            ),
        )

    emit(
        "sdr_fakequant",
        jax.jit(fakequant).lower(
            jax.ShapeDtypeStruct((64, 256), jnp.float32),
            jax.ShapeDtypeStruct((1, 1), jnp.float32),
        ),
    )

    meta = {
        "model": {
            "name": cfg.name,
            "vocab": cfg.vocab,
            "dim": cfg.dim,
            "layers": cfg.layers,
            "heads": cfg.heads,
            "kv_heads": cfg.kv_heads,
            "ffn_hidden": cfg.ffn_hidden,
            "seq_max": cfg.seq_max,
        },
        "train": {"batch": args.train_batch, "seq": args.train_seq},
        "eval": {"batch": 1, "seq": args.eval_seq},
        "sdr_kernel": {"rows": 64, "cols": 256, "base_bits": 16,
                       "target_bits": 4, "group": 16},
        "params": [{"name": n, "shape": list(s)} for n, s in order],
        "artifacts": artifacts,
    }
    (outdir / "meta.json").write_text(json.dumps(meta, indent=2))
    print(f"wrote {outdir / 'meta.json'}")

    if args.out:  # legacy: copy the fp logits artifact to --out
        pathlib.Path(args.out).write_text((outdir / "lm_logits_fp.hlo.txt").read_text())


if __name__ == "__main__":
    main()
