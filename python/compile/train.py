"""L2 training step: Adam on next-token cross-entropy.

The whole optimizer lives inside the lowered `train_step` HLO, so the
Rust driver (examples/e2e_train_serve.rs) only shuttles flat parameter/
moment arrays in and out of PJRT — python never runs at training time.
Flat ordering follows `model.param_order`.
"""

import jax
import jax.numpy as jnp

from . import model as M

LR = 3e-3
BETA1 = 0.9
BETA2 = 0.95
EPS = 1e-8
WD = 0.01


def train_step_flat(cfg: M.Config, step, tokens, *flat):
    """One Adam step on flattened state.

    `flat` = params ++ m ++ v (each `len(order)` arrays).
    Returns (loss, new_params ++ new_m ++ new_v).
    """
    order = [n for n, _ in M.param_order(cfg)]
    n = len(order)
    assert len(flat) == 3 * n, f"expected {3 * n} arrays, got {len(flat)}"
    params = dict(zip(order, flat[:n]))
    m = dict(zip(order, flat[n : 2 * n]))
    v = dict(zip(order, flat[2 * n :]))

    loss, grads = jax.value_and_grad(M.loss_fn)(params, tokens, cfg)

    t = step + 1.0
    bc1 = 1.0 - BETA1 ** t
    bc2 = 1.0 - BETA2 ** t
    new_p, new_m, new_v = [], [], []
    for name in order:
        g = grads[name]
        mi = BETA1 * m[name] + (1.0 - BETA1) * g
        vi = BETA2 * v[name] + (1.0 - BETA2) * g * g
        update = (mi / bc1) / (jnp.sqrt(vi / bc2) + EPS)
        decay = 0.0 if name.endswith("norm") else WD
        new_p.append(params[name] - LR * (update + decay * params[name]))
        new_m.append(mi)
        new_v.append(vi)
    return (loss, *new_p, *new_m, *new_v)


def zero_moments(cfg: M.Config):
    """Initial Adam state (zeros shaped like the parameters)."""
    return [jnp.zeros(shape, jnp.float32) for _, shape in M.param_order(cfg)]
