"""Pure-jnp oracle for the QRazor transform (L1 correctness reference).

Implements the paper's two stages exactly, vectorized over the last
axis, with no Pallas involvement: `absmax_quant` (stage 1) and
`sdr_fake_quant` (stage 2: leading-one razoring + RTN with the all-ones
floor guard). The Pallas kernels in `sdr.py` must match this oracle
bit-for-bit (the dequantized lattices are integer multiples of the
scale, so equality is exact, not approximate) — enforced by
`python/tests/test_kernels.py` under hypothesis sweeps. The same
semantics are implemented bit-level in Rust (`rust/src/sdr/razor.rs`).
"""

import jax
import jax.numpy as jnp


def qmax(bits: int) -> int:
    """Largest representable magnitude for a signed bit width."""
    return (1 << (bits - 1)) - 1


def absmax_scale(x: jnp.ndarray, bits: int) -> jnp.ndarray:
    """Per-tensor absolute-max scale: |x|_max / qmax (0 for zero input)."""
    amax = jnp.max(jnp.abs(x))
    return jnp.where(amax > 0, amax / qmax(bits), 0.0)


def absmax_quant(x: jnp.ndarray, scale: jnp.ndarray, bits: int) -> jnp.ndarray:
    """Stage 1: round-to-nearest-even symmetric quantization to int32."""
    q = qmax(bits)
    inv = jnp.where(scale > 0, 1.0 / scale, 0.0)
    # jnp.round implements round-half-even, matching Rust's
    # round_ties_even — required for exact cross-language parity.
    return jnp.clip(jnp.round(x * inv), -q, q).astype(jnp.int32)


def sdr_compress_int(q: jnp.ndarray, base_bits: int, target_bits: int,
                     group: int):
    """Stage 2 on integer values: returns (codes, flags, signs).

    `q` has shape [..., n] with n divisible by `group`. Codes are the
    salient magnitudes (target_bits-1 wide), flags the per-group LSB
    truncation counts.
    """
    del base_bits  # width is implied by the int32 values
    sal = target_bits - 1
    all_ones = (1 << sal) - 1
    mag = jnp.abs(q)
    shape = mag.shape
    n = shape[-1]
    assert n % group == 0, f"last dim {n} not divisible by group {group}"
    gshape = shape[:-1] + (n // group, group)
    mg = mag.reshape(gshape)
    # group bitwise-OR (the razoring-point detector, Appendix A.2)
    m_or = jax.lax.reduce(mg, jnp.int32(0), jax.lax.bitwise_or, (len(gshape) - 1,))
    # leading-one index = 31 - clz; flag = max(r - (sal-1), 0)
    r = 31 - jax.lax.clz(jnp.maximum(m_or, 1))
    flag = jnp.where(m_or > 0, jnp.maximum(r - (sal - 1), 0), 0).astype(jnp.int32)
    flag_b = jnp.repeat(flag[..., None], group, axis=-1).reshape(shape)
    trunc = jax.lax.shift_right_logical(mag, flag_b)
    round_bit = jnp.where(
        flag_b > 0,
        jax.lax.shift_right_logical(mag, jnp.maximum(flag_b - 1, 0)) & 1,
        0,
    )
    # all-ones floor guard (Algorithm 1)
    codes = jnp.where(trunc == all_ones, trunc, trunc + round_bit)
    return codes, flag, jnp.sign(q)


def sdr_fake_quant(x: jnp.ndarray, scale: jnp.ndarray, base_bits: int,
                   target_bits: int, group: int) -> jnp.ndarray:
    """Full QRazor fake-quant: stage 1 + stage 2 + dequantize.

    When target_bits >= base_bits, stage 2 is the identity (the Table 1
    base-precision scenarios).
    """
    q = absmax_quant(x, scale, base_bits)
    if target_bits >= base_bits:
        return q.astype(jnp.float32) * scale
    codes, flag, sign = sdr_compress_int(q, base_bits, target_bits, group)
    flag_b = jnp.repeat(flag[..., None], group, axis=-1).reshape(x.shape)
    recon = jax.lax.shift_left(codes, flag_b)
    return (sign * recon).astype(jnp.float32) * scale


def qrazor_weight_ref(w, group: int, target_bits: int = 4) -> jnp.ndarray:
    """Per-channel (row) weight fake-quant: 8-bit base + SDR to
    `target_bits`."""
    w_amax = jnp.max(jnp.abs(w), axis=1, keepdims=True)
    w_scale = jnp.where(w_amax > 0, w_amax / qmax(8), 0.0)
    qw = jnp.clip(jnp.round(w / jnp.where(w_scale > 0, w_scale, 1.0)),
                  -127, 127).astype(jnp.int32)
    qw = jnp.where(w_amax > 0, qw, 0)
    if target_bits >= 8:
        return qw.astype(jnp.float32) * w_scale
    codes, flag, sign = sdr_compress_int(qw, 8, target_bits, group)
    flag_b = jnp.repeat(flag[..., None], group, axis=-1).reshape(w.shape)
    return (sign * jax.lax.shift_left(codes, flag_b)).astype(jnp.float32) * w_scale


def qrazor_linear_ref(x, w, x_scale, w_group, a_group, a_target: int = 4,
                      w_target: int = 4):
    """Reference quantized linear: y = Q_a(x) @ Q_w(w)^T.

    Weights: per-channel (row) 8-bit base, SDR to `w_target`, group
    `w_group`. Activations: per-tensor static 16-bit base, SDR to
    `a_target`, group `a_group`.
    """
    w_hat = qrazor_weight_ref(w, w_group, w_target)
    x_hat = sdr_fake_quant(x, x_scale, 16, a_target, a_group)
    return x_hat @ w_hat.T
