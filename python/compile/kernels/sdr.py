"""L1 Pallas kernels: SDR fake-quantization and the razored linear.

Two kernels, both lowered with ``interpret=True`` (the CPU PJRT plugin
cannot execute Mosaic custom-calls; see DESIGN.md §9 for the real-TPU
mapping):

* :func:`sdr_fake_quant_pallas` — tiles the input over rows, performs
  the full stage-1 + stage-2 QRazor transform per tile on the VPU
  (integer ops only between the two scale multiplies).
* :func:`qrazor_linear_pallas` — the paper's compute hot-spot: a tiled
  ``Q_a(x) @ Q_w(w)ᵀ`` where both operands are fake-quantized *inside*
  the kernel. BlockSpec streams (bm × K) activation tiles and
  (bn × K) weight tiles HBM→VMEM; the MXU-shaped ``jnp.dot`` consumes
  them. On real TPU the dequant shift folds into the accumulator scale
  (the barrel-shifter-as-exp2-multiply described in DESIGN.md §9).

Both are bit-exact against ``ref.py`` — integer lattices, no tolerance.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import ref


def _sdr_tile(x, scale, base_bits: int, target_bits: int, group: int):
    """The in-kernel SDR transform on one VMEM tile (pure jnp ops)."""
    q = ref.absmax_quant(x, scale, base_bits)
    if target_bits >= base_bits:
        return q.astype(jnp.float32) * scale
    sal = target_bits - 1
    all_ones = (1 << sal) - 1
    mag = jnp.abs(q)
    rows, n = x.shape
    mg = mag.reshape(rows, n // group, group)
    m_or = jax.lax.reduce(mg, jnp.int32(0), jax.lax.bitwise_or, (2,))
    r = 31 - jax.lax.clz(jnp.maximum(m_or, 1))
    flag = jnp.where(m_or > 0, jnp.maximum(r - (sal - 1), 0), 0)
    flag_b = jnp.repeat(flag[..., None], group, axis=-1).reshape(rows, n)
    trunc = jax.lax.shift_right_logical(mag, flag_b)
    round_bit = jnp.where(
        flag_b > 0,
        jax.lax.shift_right_logical(mag, jnp.maximum(flag_b - 1, 0)) & 1,
        0,
    )
    codes = jnp.where(trunc == all_ones, trunc, trunc + round_bit)
    recon = jax.lax.shift_left(codes, flag_b)
    return (jnp.sign(q) * recon).astype(jnp.float32) * scale


def sdr_fake_quant_pallas(x, scale, *, base_bits: int, target_bits: int,
                          group: int, block_rows: int = 64):
    """QRazor fake-quant of a 2-D array, tiled over rows.

    ``scale`` is a (1, 1) array (static per-tensor scale as an operand,
    so one compiled kernel serves every calibrated site).
    """
    rows, n = x.shape
    assert n % group == 0, f"{n} % {group}"
    bm = min(block_rows, rows)
    assert rows % bm == 0, f"rows {rows} not divisible by block {bm}"

    def kernel(x_ref, s_ref, o_ref):
        o_ref[...] = _sdr_tile(x_ref[...], s_ref[0, 0], base_bits,
                               target_bits, group)

    return pl.pallas_call(
        kernel,
        grid=(rows // bm,),
        in_specs=[
            pl.BlockSpec((bm, n), lambda i: (i, 0)),
            pl.BlockSpec((1, 1), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((bm, n), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((rows, n), jnp.float32),
        interpret=True,
    )(x, scale)


def qrazor_linear_pallas(x, w, x_scale, *, w_group: int = 16,
                         a_group: int = 16, block_m: int = 64,
                         block_n: int = 64):
    """Quantized linear ``y = Q_a(x) @ Q_w(w)ᵀ`` as a tiled Pallas kernel.

    ``x``: [M, K] activations, per-tensor static scale ``x_scale`` (1,1).
    ``w``: [N, K] weights, per-channel scales computed in-kernel.
    Grid tiles (block_m × K) × (block_n × K); K is kept whole per tile —
    our model dims (≤1k) fit VMEM comfortably (DESIGN.md §9 budgets it).
    """
    m, k = x.shape
    n, k2 = w.shape
    assert k == k2
    bm, bn = min(block_m, m), min(block_n, n)
    assert m % bm == 0 and n % bn == 0, f"{m}%{bm} / {n}%{bn}"

    def kernel(x_ref, w_ref, s_ref, o_ref):
        xt = _sdr_tile(x_ref[...], s_ref[0, 0], 16, 4, a_group)
        # per-channel stage-1 + SDR on the weight tile (rows are whole
        # output channels, so tiling over n preserves per-channel scales)
        w_hat = ref.qrazor_weight_ref(w_ref[...], w_group, 4)
        # MXU-shaped contraction on the dequantized lattices
        o_ref[...] = jnp.dot(xt, w_hat.T, preferred_element_type=jnp.float32)

    return pl.pallas_call(
        kernel,
        grid=(m // bm, n // bn),
        in_specs=[
            pl.BlockSpec((bm, k), lambda i, j: (i, 0)),
            pl.BlockSpec((bn, k), lambda i, j: (j, 0)),
            pl.BlockSpec((1, 1), lambda i, j: (0, 0)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        interpret=True,
    )(x, w, x_scale)


@functools.partial(jax.jit, static_argnames=("base_bits", "target_bits", "group"))
def sdr_fake_quant_jit(x, scale, base_bits: int, target_bits: int, group: int):
    """Jitted oracle wrapper (used by model.py when Pallas is disabled)."""
    return ref.sdr_fake_quant(x, scale, base_bits, target_bits, group)
