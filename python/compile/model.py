"""L2: the JAX transformer — same architecture and parameter naming as
the Rust reference (`rust/src/model/mod.rs`), so checkpoints and logits
cross the language boundary exactly.

Architecture: token embedding → N × (RMSNorm → MHA with RoPE (GQA-aware)
→ residual → RMSNorm → SwiGLU → residual) → final RMSNorm → lm_head.
Optionally every GEMM boundary is routed through the L1 QRazor kernels
(`quant=` config) to produce the quantized-serving artifact.

Build-time only: this module is imported by `aot.py` and the pytest
suite, never by the Rust runtime.
"""

import dataclasses
import functools

import jax
import jax.numpy as jnp

from .kernels import ref as kref
from .kernels import sdr as ksdr


@dataclasses.dataclass(frozen=True)
class Config:
    name: str
    vocab: int
    dim: int
    layers: int
    heads: int
    kv_heads: int
    ffn_hidden: int
    seq_max: int

    @property
    def head_dim(self) -> int:
        return self.dim // self.heads

    @property
    def kv_dim(self) -> int:
        return self.head_dim * self.kv_heads


# Mirrors rust/src/config.rs presets exactly.
PRESETS = {
    "nano": Config("nano", 256, 64, 2, 2, 2, 128, 128),
    "tiny": Config("tiny", 512, 256, 4, 4, 4, 512, 256),
    "small": Config("small", 512, 512, 6, 8, 8, 1024, 256),
    "mistral-tiny": Config("mistral-tiny", 512, 256, 4, 8, 2, 512, 256),
    "medium": Config("medium", 4096, 768, 12, 12, 12, 2048, 512),
}


def param_order(cfg: Config):
    """Canonical (name, shape) list — must match
    ModelWeights::param_specs in rust/src/model/mod.rs."""
    out = [("embed", (cfg.vocab, cfg.dim))]
    for li in range(cfg.layers):
        out += [
            (f"layers.{li}.attn_norm", (cfg.dim,)),
            (f"layers.{li}.wq", (cfg.dim, cfg.dim)),
            (f"layers.{li}.wk", (cfg.kv_dim, cfg.dim)),
            (f"layers.{li}.wv", (cfg.kv_dim, cfg.dim)),
            (f"layers.{li}.wo", (cfg.dim, cfg.dim)),
            (f"layers.{li}.ffn_norm", (cfg.dim,)),
            (f"layers.{li}.w_gate", (cfg.ffn_hidden, cfg.dim)),
            (f"layers.{li}.w_up", (cfg.ffn_hidden, cfg.dim)),
            (f"layers.{li}.w_down", (cfg.dim, cfg.ffn_hidden)),
        ]
    out += [("final_norm", (cfg.dim,)), ("lm_head", (cfg.vocab, cfg.dim))]
    return out


def init_params(cfg: Config, key) -> dict:
    """1/sqrt(fan_in) normal init; norms start at 1."""
    params = {}
    for name, shape in param_order(cfg):
        key, sub = jax.random.split(key)
        if name.endswith("norm"):
            params[name] = jnp.ones(shape, jnp.float32)
        else:
            fan_in = shape[-1]
            params[name] = (
                jax.random.normal(sub, shape, jnp.float32) / jnp.sqrt(fan_in)
            )
    return params


def rmsnorm(x, gain, eps=1e-5):
    ms = jnp.mean(x * x, axis=-1, keepdims=True)
    return x * jax.lax.rsqrt(ms + eps) * gain


def apply_rope(x, n_heads, head_dim, pos0=0):
    """RoPE with pairing (i, i+half) — identical to the Rust version."""
    t = x.shape[-2]
    half = head_dim // 2
    pos = jnp.arange(pos0, pos0 + t, dtype=jnp.float32)[:, None]
    idx = jnp.arange(half, dtype=jnp.float32)[None, :]
    theta = pos / (10_000.0 ** (2.0 * idx / head_dim))
    # [t, 1, half] so it broadcasts across the head axis of xh
    sin, cos = jnp.sin(theta)[:, None, :], jnp.cos(theta)[:, None, :]
    shape = x.shape[:-1] + (n_heads, head_dim)
    xh = x.reshape(shape)
    a = xh[..., :half]
    b = xh[..., half:]
    ra = a * cos - b * sin
    rb = b * cos + a * sin
    return jnp.concatenate([ra, rb], axis=-1).reshape(x.shape)


@dataclasses.dataclass(frozen=True)
class QuantConfig:
    """QRazor fake-quant settings for the serving artifact. Scales are
    computed dynamically in-graph (per-tensor absmax) — the Rust path
    with calibrated static scales is the normative accuracy pipeline;
    this artifact exists to run the L1 kernels end-to-end in the lowered
    HLO."""
    a_group: int = 16
    w_group: int = 16
    a_target: int = 4
    w_target: int = 4
    use_pallas: bool = True


def _quant_linear(x2d, w, qc: QuantConfig):
    """Quantized y = Q_a(x) @ Q_w(w)^T on 2-D x."""
    scale = kref.absmax_scale(x2d, 16).reshape(1, 1)
    if qc.use_pallas:
        return ksdr.qrazor_linear_pallas(
            x2d, w, scale, w_group=qc.w_group, a_group=qc.a_group
        )
    return kref.qrazor_linear_ref(x2d, w, scale[0, 0], qc.w_group,
                                  qc.a_group, qc.a_target, qc.w_target)


def _linear(x, w, qc):
    """x [..., k] @ w[n, k]^T with optional quantization."""
    if qc is None:
        return x @ w.T
    lead = x.shape[:-1]
    x2d = x.reshape(-1, x.shape[-1])
    y = _quant_linear(x2d, w, qc)
    return y.reshape(lead + (w.shape[0],))


def forward(params: dict, tokens, cfg: Config, qc: QuantConfig | None = None):
    """Full-sequence causal forward → logits [batch, seq, vocab]."""
    b, t = tokens.shape
    hd = cfg.head_dim
    x = params["embed"][tokens]  # [b, t, dim]
    mask = jnp.tril(jnp.ones((t, t), bool))
    for li in range(cfg.layers):
        p = lambda n: params[f"layers.{li}.{n}"]
        h = rmsnorm(x, p("attn_norm"))
        q = _linear(h, p("wq"), qc)
        k = _linear(h, p("wk"), qc)
        v = _linear(h, p("wv"), qc)
        q = apply_rope(q, cfg.heads, hd)
        k = apply_rope(k, cfg.kv_heads, hd)
        qh = q.reshape(b, t, cfg.heads, hd).transpose(0, 2, 1, 3)
        kh = k.reshape(b, t, cfg.kv_heads, hd).transpose(0, 2, 1, 3)
        vh = v.reshape(b, t, cfg.kv_heads, hd).transpose(0, 2, 1, 3)
        if cfg.kv_heads != cfg.heads:
            rep = cfg.heads // cfg.kv_heads
            kh = jnp.repeat(kh, rep, axis=1)
            vh = jnp.repeat(vh, rep, axis=1)
        scores = jnp.einsum("bhqd,bhkd->bhqk", qh, kh) / jnp.sqrt(float(hd))
        scores = jnp.where(mask[None, None], scores, -1e30)
        probs = jax.nn.softmax(scores, axis=-1)
        ctx = jnp.einsum("bhqk,bhkd->bhqd", probs, vh)
        ctx = ctx.transpose(0, 2, 1, 3).reshape(b, t, cfg.dim)
        x = x + _linear(ctx, p("wo"), qc)
        h = rmsnorm(x, p("ffn_norm"))
        gate = _linear(h, p("w_gate"), qc)
        up = _linear(h, p("w_up"), qc)
        act = jax.nn.silu(gate) * up
        x = x + _linear(act, p("w_down"), qc)
    x = rmsnorm(x, params["final_norm"])
    return _linear(x, params["lm_head"], qc)


def loss_fn(params, tokens, cfg: Config):
    """Next-token cross entropy (mean over positions)."""
    logits = forward(params, tokens, cfg)
    logp = jax.nn.log_softmax(logits[:, :-1], axis=-1)
    targets = tokens[:, 1:]
    nll = -jnp.take_along_axis(logp, targets[..., None], axis=-1)
    return jnp.mean(nll)


@functools.partial(jax.jit, static_argnames=("preset",))
def logits_fp(tokens, *flat_params, preset: str):
    cfg = PRESETS[preset]
    names = [n for n, _ in param_order(cfg)]
    params = dict(zip(names, flat_params))
    return forward(params, tokens, cfg)
